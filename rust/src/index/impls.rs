//! [`AnnIndex`] implementors: one thin wrapper per family pairing a
//! shared data matrix (`Arc<Matrix>` — datasets are shared, not copied,
//! across index variants) with the family's graph/codebook state. The
//! family modules keep their borrowed-data search methods; these wrappers
//! are the self-contained objects the server, sweeps, CLI, and
//! persistence operate on.
//!
//! Every search-bearing wrapper additionally owns a
//! [`VectorStore`] — the padded, aligned query-time copy of the rows its
//! hot loops score against. The `Matrix` stays the build/IO/persistence
//! container; the store is rebuilt from it on load and compaction and
//! extended in lockstep on insert. Padding is numerically invisible (see
//! `core::distance`), so store-backed searches return bit-identical
//! results to matrix-backed ones.
//!
//! Construction parallelism rides inside each family's params struct
//! (`threads`, 0 = auto): the graph builds and FINGER training are
//! deterministic under any worker count, and compaction rebuilds inherit
//! the same params — so a compacted index is as reproducible as a fresh
//! build.

use std::io;
use std::sync::Arc;

use crate::core::matrix::Matrix;
use crate::core::store::VectorStore;
use crate::data::io::BinWriter;
use crate::data::persist;
use crate::finger::construct::{FingerIndex, FingerParams};
use crate::finger::search::{search_hnsw_with_index, FingerHnsw};
use crate::finger::search::finger_beam_search_approx_filtered;
use crate::graph::bruteforce::{scan, scan_live};
use crate::graph::hnsw::{Hnsw, HnswParams};
use crate::graph::nndescent::{NnDescent, NnDescentParams};
use crate::graph::search::{
    beam_search_approx_filtered, greedy_descent, rerank_exact, AllLive, LiveFilter, Neighbor,
};
use crate::graph::vamana::{Vamana, VamanaParams};
use crate::index::context::{SearchContext, SearchParams};
use crate::index::mutable::{LiveIds, MutableAnnIndex, MutateError, DEFAULT_COMPACT_THRESHOLD};
use crate::index::AnnIndex;
use crate::quant::ivfpq::{IvfPq, IvfPqParams};
use crate::quant::sq8::{Precision, QuantTier};

/// Rebuild a matrix from the live rows named by `keep`, in order (shared
/// by every family's compaction, including the sharded parent's).
pub(crate) fn gather_rows(data: &Matrix, keep: &[usize]) -> Arc<Matrix> {
    let mut m = Matrix::zeros(0, data.cols());
    for &row in keep {
        m.push_row(data.row(row));
    }
    Arc::new(m)
}

type PayloadWriter<'a, 'b> = &'a mut BinWriter<&'b mut dyn io::Write>;

/// Quantized traversal + exact re-rank, shared by the HNSW-shaped
/// families. The upper layers are descended with exact f32 distances
/// (they hold a vanishing fraction of the distance work), the base-layer
/// beam runs entirely on the tier's approximate scorer — composed with
/// the FINGER screen when `finger` is given — and the full candidate pool
/// is then re-scored with the exact kernels and truncated to `k`, which
/// restores f32 ordering of everything the approximate beam surfaced.
/// `params.patience` is ignored in quantized mode (the approximate core
/// has no early-termination arm).
fn quant_graph_search<F: LiveFilter + ?Sized>(
    tier: &QuantTier,
    store: &VectorStore,
    graph: &Hnsw,
    finger: Option<&FingerIndex>,
    q: &[f32],
    params: &SearchParams,
    filter: &F,
    ctx: &mut SearchContext,
) -> Vec<Neighbor> {
    if store.rows() == 0 {
        return Vec::new();
    }
    let mut cur = graph.entry;
    for l in (1..=graph.max_level).rev() {
        cur = greedy_descent(store, &graph.upper[l - 1], cur, q, ctx).id;
    }
    // The scorer borrows the pooled qcodes/qtable scratch; take the
    // buffers out of the context so it can be handed to the core mutably.
    let mut qcodes = std::mem::take(&mut ctx.qcodes);
    let mut qtable = std::mem::take(&mut ctx.qtable);
    let mut pool = {
        let mut scorer = tier.scorer(q, &mut qcodes, &mut qtable);
        match finger {
            Some(findex) => finger_beam_search_approx_filtered(
                store.rows(),
                &graph.base,
                findex,
                cur,
                q,
                params.beam_width(),
                filter,
                &mut scorer,
                ctx,
            ),
            None => beam_search_approx_filtered(
                store.rows(),
                &graph.base,
                cur,
                params.beam_width(),
                filter,
                &mut scorer,
                ctx,
            ),
        }
    };
    ctx.qcodes = qcodes;
    ctx.qtable = qtable;
    let mut qp = std::mem::take(&mut ctx.qbuf);
    store.pad_query(q, &mut qp);
    rerank_exact(store, &qp, &mut pool, !params.scalar_kernels, ctx);
    ctx.qbuf = qp;
    pool.truncate(params.k);
    pool
}

/// The [`MutableAnnIndex`] methods that are pure [`LiveIds`] bookkeeping,
/// identical for every flat family (`insert`/`compact` stay hand-written
/// per family). One definition, so the delete/report semantics cannot
/// drift between implementors.
macro_rules! delegate_live_bookkeeping {
    () => {
        fn remove(&mut self, id: u32) -> Result<(), MutateError> {
            let row = self.live.row_of(id).ok_or(MutateError::UnknownId(id))?;
            if !self.live.kill_row(row) {
                return Err(MutateError::AlreadyDeleted(id));
            }
            Ok(())
        }

        fn live_len(&self) -> usize {
            self.live.live_len()
        }

        fn is_live(&self, id: u32) -> bool {
            self.live.is_live(id)
        }

        fn live_ids(&self) -> Vec<u32> {
            self.live.live_ids()
        }

        fn tombstone_fraction(&self) -> f64 {
            self.live.tombstone_fraction()
        }

        fn set_compact_threshold(&mut self, frac: f64) {
            self.compact_threshold = frac;
        }

        fn compact_threshold(&self) -> f64 {
            self.compact_threshold
        }
    };
}

/// One small instance of every family over `data` — shared by the
/// persistence-roundtrip and trait-conformance suites (and handy for
/// demos), so a new family is registered in exactly one place.
/// When adding a family here, mirror it in
/// [`crate::index::sharded::build_all_families_sharded`] (and its label
/// match) so the sharded conformance coverage keeps pace.
pub fn build_all_families(data: Arc<Matrix>) -> Vec<Box<dyn AnnIndex>> {
    vec![
        Box::new(BruteForce::new(Arc::clone(&data))),
        Box::new(HnswIndex::build(
            Arc::clone(&data),
            HnswParams { m: 12, ef_construction: 80, ..Default::default() },
        )),
        Box::new(FingerHnswIndex::build(
            Arc::clone(&data),
            HnswParams { m: 12, ef_construction: 80, ..Default::default() },
            FingerParams { rank: 8, ..Default::default() },
        )),
        Box::new(VamanaIndex::build(Arc::clone(&data), VamanaParams::default())),
        Box::new(NnDescentIndex::build(
            Arc::clone(&data),
            NnDescentParams::default(),
        )),
        Box::new(IvfPqIndex::build(
            Arc::clone(&data),
            IvfPqParams { n_list: 16, ..Default::default() },
        )),
        // Quantized-traversal variants (appended at the end so the tag
        // order of the first six families — and every fixture that pins
        // it — is unchanged).
        Box::new(BruteForce::with_precision(Arc::clone(&data), Precision::Sq8)),
        Box::new(HnswIndex::build_with_precision(
            Arc::clone(&data),
            HnswParams { m: 12, ef_construction: 80, ..Default::default() },
            Precision::Sq8,
        )),
        Box::new(HnswIndex::build_with_precision(
            Arc::clone(&data),
            HnswParams { m: 12, ef_construction: 80, ..Default::default() },
            Precision::Pq,
        )),
        Box::new(FingerHnswIndex::build_with_precision(
            data,
            HnswParams { m: 12, ef_construction: 80, ..Default::default() },
            FingerParams { rank: 8, ..Default::default() },
            Precision::Sq8,
        )),
    ]
}

/// Exact linear scan — the reference implementor every other family is
/// conformance-tested against. Fully mutable: inserts append rows,
/// deletes tombstone them out of the scan, compaction drops them. The
/// scan itself runs batched over the padded store.
pub struct BruteForce {
    pub data: Arc<Matrix>,
    store: VectorStore,
    live: LiveIds,
    compact_threshold: f64,
    quant: Option<QuantTier>,
}

impl BruteForce {
    pub fn new(data: Arc<Matrix>) -> BruteForce {
        let live = LiveIds::fresh(data.rows());
        let store = VectorStore::from_matrix(&data);
        BruteForce { data, store, live, compact_threshold: DEFAULT_COMPACT_THRESHOLD, quant: None }
    }

    /// Build with a quantized traversal tier: the scan scores the codes,
    /// a shortlist of `rerank_width()` survivors is re-ranked exactly.
    pub fn with_precision(data: Arc<Matrix>, precision: Precision) -> BruteForce {
        let mut bf = BruteForce::new(data);
        bf.quant = QuantTier::build(precision, &bf.data);
        bf
    }

    /// Attach a loaded quantized tier (the v6 loader's entry).
    pub fn with_quant(mut self, quant: Option<QuantTier>) -> BruteForce {
        if let Some(t) = &quant {
            assert_eq!(t.rows(), self.data.rows(), "quant tier must cover the rows");
        }
        self.quant = quant;
        self
    }

    pub fn quant(&self) -> Option<&QuantTier> {
        self.quant.as_ref()
    }

    /// Approximate scan over the quantized tier + exact re-rank of the
    /// shortlist. Ids in the pool are rows until the final remap.
    fn scan_quant(
        &self,
        tier: &QuantTier,
        q: &[f32],
        params: &SearchParams,
        ctx: &mut SearchContext,
    ) -> Vec<Neighbor> {
        let identity = self.live.is_identity();
        let mut qcodes = std::mem::take(&mut ctx.qcodes);
        let mut qtable = std::mem::take(&mut ctx.qtable);
        let mut pool: Vec<Neighbor> = Vec::with_capacity(self.data.rows());
        {
            let mut scorer = tier.scorer(q, &mut qcodes, &mut qtable);
            for row in 0..self.data.rows() {
                if !identity && self.live.is_dead_row(row) {
                    continue;
                }
                pool.push(Neighbor { dist: scorer.dist(row), id: row as u32 });
            }
        }
        ctx.qcodes = qcodes;
        ctx.qtable = qtable;
        if ctx.stats_enabled {
            ctx.stats.approx_calls += pool.len() as u64;
        }
        pool.sort();
        pool.truncate(params.rerank_width().max(params.beam_width()));
        let mut qp = std::mem::take(&mut ctx.qbuf);
        self.store.pad_query(q, &mut qp);
        rerank_exact(&self.store, &qp, &mut pool, !params.scalar_kernels, ctx);
        ctx.qbuf = qp;
        pool.truncate(params.k);
        if !identity {
            self.live.remap_rows_to_external(&mut pool);
        }
        pool
    }

    /// Restore persisted mutation state (the v5 loader's entry).
    pub fn with_live(mut self, live: LiveIds) -> BruteForce {
        assert_eq!(live.n_rows(), self.data.rows(), "live map must cover the rows");
        self.live = live;
        self
    }

    pub fn live(&self) -> &LiveIds {
        &self.live
    }

    pub fn store(&self) -> &VectorStore {
        &self.store
    }
}

impl AnnIndex for BruteForce {
    fn name(&self) -> &'static str {
        match self.quant.as_ref().map(|t| t.precision()) {
            None => "bruteforce",
            Some(Precision::Sq8) => "bruteforce-sq8",
            Some(Precision::Pq) => "bruteforce-pq",
            Some(Precision::F32) => unreachable!("F32 never builds a tier"),
        }
    }

    fn dim(&self) -> usize {
        self.data.cols()
    }

    fn len(&self) -> usize {
        self.data.rows()
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn nbytes(&self) -> usize {
        self.quant.as_ref().map_or(0, |t| t.nbytes())
    }

    fn search(&self, q: &[f32], params: &SearchParams, ctx: &mut SearchContext) -> Vec<Neighbor> {
        if let Some(tier) = &self.quant {
            return self.scan_quant(tier, q, params, ctx);
        }
        if self.live.is_identity() {
            if ctx.stats_enabled {
                ctx.stats.dist_calls += self.data.rows() as u64;
            }
            return scan(&self.store, q, params.k);
        }
        if ctx.stats_enabled {
            ctx.stats.dist_calls += self.live.live_len() as u64;
        }
        scan_live(&self.store, q, params.k, &self.live)
    }

    fn as_mutable(&mut self) -> Option<&mut dyn MutableAnnIndex> {
        Some(self)
    }

    fn as_mutable_view(&self) -> Option<&dyn MutableAnnIndex> {
        Some(self)
    }

    fn kind_tag(&self) -> u64 {
        persist::TAG_BRUTEFORCE
    }

    fn save_payload(&self, w: PayloadWriter) -> io::Result<()> {
        persist::save_quant(w, self.quant.as_ref())?; // quant before live: live stays at tail
        self.live.save(w)
    }
}

impl MutableAnnIndex for BruteForce {
    fn insert(&mut self, v: &[f32], _ctx: &mut SearchContext) -> Result<u32, MutateError> {
        if self.data.cols() != 0 && v.len() != self.data.cols() {
            return Err(MutateError::DimMismatch { got: v.len(), want: self.data.cols() });
        }
        Arc::make_mut(&mut self.data).push_row(v);
        self.store.push_row(v);
        if let Some(t) = &mut self.quant {
            t.push_row(v); // frozen codec/codebooks
        }
        Ok(self.live.alloc())
    }

    fn compact(&mut self, _ctx: &mut SearchContext) -> Result<bool, MutateError> {
        if !self.live.should_compact(self.compact_threshold) {
            return Ok(false);
        }
        let plan = self.live.compact_plan();
        self.data = gather_rows(&self.data, &plan);
        self.store = VectorStore::from_matrix(&self.data);
        if let Some(t) = &mut self.quant {
            t.gather_rows(&plan); // codes gathered verbatim, codec frozen
        }
        self.live.apply_compact();
        Ok(true)
    }

    delegate_live_bookkeeping!();
}

/// Plain HNSW (Algorithm 1 search). Mutable: inserts run the incremental
/// construction-time insertion over the pooled beam search; deletes are
/// tombstones consulted at result emission but not during traversal (so
/// graph connectivity survives); compaction rebuilds over the live set
/// once the tombstone fraction crosses the threshold.
pub struct HnswIndex {
    pub data: Arc<Matrix>,
    pub graph: Hnsw,
    store: VectorStore,
    live: LiveIds,
    compact_threshold: f64,
    quant: Option<QuantTier>,
}

impl HnswIndex {
    pub fn build(data: Arc<Matrix>, params: HnswParams) -> HnswIndex {
        let store = VectorStore::from_matrix(&data);
        let graph = Hnsw::build_with_store(&store, params);
        let live = LiveIds::fresh(data.rows());
        HnswIndex { data, graph, store, live, compact_threshold: DEFAULT_COMPACT_THRESHOLD, quant: None }
    }

    /// Build with a quantized traversal tier over the same graph: the
    /// base-layer beam scores codes, the final pool re-ranks exactly.
    /// The graph itself is identical to the F32 build (construction stays
    /// full-precision), so precision is purely a search-time trade.
    pub fn build_with_precision(
        data: Arc<Matrix>,
        params: HnswParams,
        precision: Precision,
    ) -> HnswIndex {
        let mut ix = HnswIndex::build(data, params);
        ix.quant = QuantTier::build(precision, &ix.data);
        ix
    }

    pub fn from_parts(data: Arc<Matrix>, graph: Hnsw) -> HnswIndex {
        let store = VectorStore::from_matrix(&data);
        let live = LiveIds::fresh(data.rows());
        HnswIndex { data, graph, store, live, compact_threshold: DEFAULT_COMPACT_THRESHOLD, quant: None }
    }

    /// Attach a loaded quantized tier (the v6 loader's entry).
    pub fn with_quant(mut self, quant: Option<QuantTier>) -> HnswIndex {
        if let Some(t) = &quant {
            assert_eq!(t.rows(), self.data.rows(), "quant tier must cover the rows");
        }
        self.quant = quant;
        self
    }

    pub fn quant(&self) -> Option<&QuantTier> {
        self.quant.as_ref()
    }

    /// Restore persisted mutation state (the v5 loader's entry).
    pub fn with_live(mut self, live: LiveIds) -> HnswIndex {
        assert_eq!(live.n_rows(), self.data.rows(), "live map must cover the rows");
        self.live = live;
        self
    }

    pub fn live(&self) -> &LiveIds {
        &self.live
    }

    /// The padded query-time store (for callers that drive the family
    /// search methods directly, e.g. benches).
    pub fn store(&self) -> &VectorStore {
        &self.store
    }
}

impl AnnIndex for HnswIndex {
    fn name(&self) -> &'static str {
        match self.quant.as_ref().map(|t| t.precision()) {
            None => "hnsw",
            Some(Precision::Sq8) => "hnsw-sq8",
            Some(Precision::Pq) => "hnsw-pq",
            Some(Precision::F32) => unreachable!("F32 never builds a tier"),
        }
    }

    fn dim(&self) -> usize {
        self.data.cols()
    }

    fn len(&self) -> usize {
        self.data.rows()
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn nbytes(&self) -> usize {
        self.graph.nbytes() + self.quant.as_ref().map_or(0, |t| t.nbytes())
    }

    fn search(&self, q: &[f32], params: &SearchParams, ctx: &mut SearchContext) -> Vec<Neighbor> {
        if let Some(tier) = &self.quant {
            let identity = self.live.is_identity();
            let mut res = if !identity && self.live.any_dead() {
                quant_graph_search(tier, &self.store, &self.graph, None, q, params, &self.live, ctx)
            } else {
                quant_graph_search(tier, &self.store, &self.graph, None, q, params, &AllLive, ctx)
            };
            if !identity {
                self.live.remap_rows_to_external(&mut res);
            }
            return res;
        }
        if self.live.is_identity() {
            return self.graph.search(&self.store, q, params, ctx);
        }
        let mut res = if self.live.any_dead() {
            self.graph.search_live(&self.store, q, params, &self.live, ctx)
        } else {
            self.graph.search(&self.store, q, params, ctx)
        };
        self.live.remap_rows_to_external(&mut res);
        res
    }

    fn as_mutable(&mut self) -> Option<&mut dyn MutableAnnIndex> {
        Some(self)
    }

    fn as_mutable_view(&self) -> Option<&dyn MutableAnnIndex> {
        Some(self)
    }

    fn kind_tag(&self) -> u64 {
        persist::TAG_HNSW
    }

    fn save_payload(&self, w: PayloadWriter) -> io::Result<()> {
        persist::save_hnsw(w, &self.graph)?;
        persist::save_quant(w, self.quant.as_ref())?; // quant before live: live stays at tail
        self.live.save(w)
    }
}

impl MutableAnnIndex for HnswIndex {
    fn insert(&mut self, v: &[f32], ctx: &mut SearchContext) -> Result<u32, MutateError> {
        if v.len() != self.data.cols() {
            return Err(MutateError::DimMismatch { got: v.len(), want: self.data.cols() });
        }
        let row = self.data.rows() as u32;
        Arc::make_mut(&mut self.data).push_row(v);
        self.store.push_row(v);
        if let Some(t) = &mut self.quant {
            t.push_row(v); // frozen codec/codebooks
        }
        let id = self.live.alloc();
        self.graph.insert_node(&self.store, row, ctx);
        Ok(id)
    }

    fn compact(&mut self, _ctx: &mut SearchContext) -> Result<bool, MutateError> {
        // A graph index cannot rebuild over zero points; an all-dead index
        // keeps its tombstoned state (searches already return nothing).
        if !self.live.should_compact(self.compact_threshold) || self.live.live_len() == 0 {
            return Ok(false);
        }
        let plan = self.live.compact_plan();
        let data = gather_rows(&self.data, &plan);
        self.store = VectorStore::from_matrix(&data);
        self.graph = Hnsw::build_with_store(&self.store, self.graph.params.clone());
        if let Some(t) = &mut self.quant {
            t.gather_rows(&plan); // codes gathered verbatim, codec frozen
        }
        self.data = data;
        self.live.apply_compact();
        Ok(true)
    }

    delegate_live_bookkeeping!();
}

/// HNSW + FINGER screening (the paper's system). Mutable: inserts extend
/// the graph incrementally and refresh exactly the FINGER per-edge table
/// rows the insertion rewired; deletes are emission-time tombstones;
/// compaction rebuilds the graph over the live set and **re-trains the
/// FINGER residual bases** (projection, matching, tables) on it.
pub struct FingerHnswIndex {
    pub data: Arc<Matrix>,
    pub inner: FingerHnsw,
    store: VectorStore,
    live: LiveIds,
    compact_threshold: f64,
    quant: Option<QuantTier>,
}

impl FingerHnswIndex {
    pub fn build(
        data: Arc<Matrix>,
        hnsw_params: HnswParams,
        finger_params: FingerParams,
    ) -> FingerHnswIndex {
        let store = VectorStore::from_matrix(&data);
        let inner = FingerHnsw::build_with_store(&data, &store, hnsw_params, finger_params);
        let live = LiveIds::fresh(data.rows());
        FingerHnswIndex {
            data,
            inner,
            store,
            live,
            compact_threshold: DEFAULT_COMPACT_THRESHOLD,
            quant: None,
        }
    }

    /// Build with a quantized traversal tier composed with the FINGER
    /// screen: the screen prunes candidates with the rank-r estimate,
    /// survivors are scored on the codes, the pool re-ranks exactly.
    pub fn build_with_precision(
        data: Arc<Matrix>,
        hnsw_params: HnswParams,
        finger_params: FingerParams,
        precision: Precision,
    ) -> FingerHnswIndex {
        let mut ix = FingerHnswIndex::build(data, hnsw_params, finger_params);
        ix.quant = QuantTier::build(precision, &ix.data);
        ix
    }

    pub fn from_parts(data: Arc<Matrix>, inner: FingerHnsw) -> FingerHnswIndex {
        let store = VectorStore::from_matrix(&data);
        let live = LiveIds::fresh(data.rows());
        FingerHnswIndex {
            data,
            inner,
            store,
            live,
            compact_threshold: DEFAULT_COMPACT_THRESHOLD,
            quant: None,
        }
    }

    /// Attach a loaded quantized tier (the v6 loader's entry).
    pub fn with_quant(mut self, quant: Option<QuantTier>) -> FingerHnswIndex {
        if let Some(t) = &quant {
            assert_eq!(t.rows(), self.data.rows(), "quant tier must cover the rows");
        }
        self.quant = quant;
        self
    }

    pub fn quant(&self) -> Option<&QuantTier> {
        self.quant.as_ref()
    }

    /// Restore persisted mutation state (the v5 loader's entry).
    pub fn with_live(mut self, live: LiveIds) -> FingerHnswIndex {
        assert_eq!(live.n_rows(), self.data.rows(), "live map must cover the rows");
        self.live = live;
        self
    }

    pub fn live(&self) -> &LiveIds {
        &self.live
    }

    /// The padded query-time store (for callers that drive the family
    /// search methods directly, e.g. benches and the quickstart example).
    pub fn store(&self) -> &VectorStore {
        &self.store
    }
}

impl AnnIndex for FingerHnswIndex {
    fn name(&self) -> &'static str {
        match self.quant.as_ref().map(|t| t.precision()) {
            None => "hnsw-finger",
            Some(Precision::Sq8) => "hnsw-finger-sq8",
            Some(Precision::Pq) => "hnsw-finger-pq",
            Some(Precision::F32) => unreachable!("F32 never builds a tier"),
        }
    }

    fn dim(&self) -> usize {
        self.data.cols()
    }

    fn len(&self) -> usize {
        self.data.rows()
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn nbytes(&self) -> usize {
        self.inner.nbytes() + self.quant.as_ref().map_or(0, |t| t.nbytes())
    }

    fn approx_rank(&self) -> usize {
        self.inner.index.rank
    }

    fn search(&self, q: &[f32], params: &SearchParams, ctx: &mut SearchContext) -> Vec<Neighbor> {
        if let Some(tier) = &self.quant {
            let identity = self.live.is_identity();
            let graph = &self.inner.hnsw;
            let findex = Some(&self.inner.index);
            let mut res = if !identity && self.live.any_dead() {
                quant_graph_search(tier, &self.store, graph, findex, q, params, &self.live, ctx)
            } else {
                quant_graph_search(tier, &self.store, graph, findex, q, params, &AllLive, ctx)
            };
            if !identity {
                self.live.remap_rows_to_external(&mut res);
            }
            return res;
        }
        if self.live.is_identity() {
            return self.inner.search(&self.store, q, params, ctx);
        }
        let mut res = if self.live.any_dead() {
            self.inner.search_live(&self.store, q, params, &self.live, ctx)
        } else {
            self.inner.search(&self.store, q, params, ctx)
        };
        self.live.remap_rows_to_external(&mut res);
        res
    }

    fn as_mutable(&mut self) -> Option<&mut dyn MutableAnnIndex> {
        Some(self)
    }

    fn as_mutable_view(&self) -> Option<&dyn MutableAnnIndex> {
        Some(self)
    }

    fn kind_tag(&self) -> u64 {
        persist::TAG_FINGER
    }

    fn save_payload(&self, w: PayloadWriter) -> io::Result<()> {
        persist::save_hnsw(w, &self.inner.hnsw)?;
        persist::save_finger(w, &self.inner.index)?;
        persist::save_quant(w, self.quant.as_ref())?; // quant before live: live stays at tail
        self.live.save(w)
    }
}

impl MutableAnnIndex for FingerHnswIndex {
    fn insert(&mut self, v: &[f32], ctx: &mut SearchContext) -> Result<u32, MutateError> {
        if v.len() != self.data.cols() {
            return Err(MutateError::DimMismatch { got: v.len(), want: self.data.cols() });
        }
        let row = self.data.rows() as u32;
        Arc::make_mut(&mut self.data).push_row(v);
        self.store.push_row(v);
        if let Some(t) = &mut self.quant {
            t.push_row(v); // frozen codec/codebooks
        }
        let id = self.live.alloc();
        let touched = self.inner.hnsw.insert_node(&self.store, row, ctx);
        self.inner
            .index
            .append_node(&self.data, row, self.inner.hnsw.base.cap());
        for &u in &touched {
            self.inner
                .index
                .refresh_node_edges(&self.data, &self.inner.hnsw.base, u);
        }
        Ok(id)
    }

    fn compact(&mut self, _ctx: &mut SearchContext) -> Result<bool, MutateError> {
        if !self.live.should_compact(self.compact_threshold) || self.live.live_len() == 0 {
            return Ok(false);
        }
        let plan = self.live.compact_plan();
        let data = gather_rows(&self.data, &plan);
        let hnsw_params = self.inner.hnsw.params.clone();
        let finger_params = self.inner.index.params.clone();
        // Full retrain: fresh graph + fresh FINGER residual bases fit to
        // the live distribution. The quantized tier is the exception —
        // its codec stays frozen and the code rows are gathered verbatim,
        // so WAL replay reproduces it byte-for-byte.
        self.store = VectorStore::from_matrix(&data);
        self.inner =
            FingerHnsw::build_with_store(&data, &self.store, hnsw_params, finger_params);
        if let Some(t) = &mut self.quant {
            t.gather_rows(&plan);
        }
        self.data = data;
        self.live.apply_compact();
        Ok(true)
    }

    delegate_live_bookkeeping!();
}

/// Borrowing FINGER adapter: one shared HNSW graph (and one shared padded
/// store), many FINGER/RPLSH side-index variants — the Figure 6 ablation
/// shape. Searchable through `&dyn AnnIndex` like everything else,
/// without moving the graph.
pub struct FingerView<'a> {
    pub data: &'a Matrix,
    pub store: &'a VectorStore,
    pub hnsw: &'a Hnsw,
    pub findex: &'a FingerIndex,
    /// Label shown by sweeps ("finger", "rplsh", ...).
    pub label: &'static str,
}

impl AnnIndex for FingerView<'_> {
    fn name(&self) -> &'static str {
        self.label
    }

    fn dim(&self) -> usize {
        self.data.cols()
    }

    fn len(&self) -> usize {
        self.data.rows()
    }

    fn data(&self) -> &Matrix {
        self.data
    }

    fn nbytes(&self) -> usize {
        self.hnsw.nbytes() + self.findex.nbytes()
    }

    fn approx_rank(&self) -> usize {
        self.findex.rank
    }

    fn search(&self, q: &[f32], params: &SearchParams, ctx: &mut SearchContext) -> Vec<Neighbor> {
        search_hnsw_with_index(self.hnsw, self.findex, self.store, q, params, ctx)
    }

    fn kind_tag(&self) -> u64 {
        persist::TAG_FINGER
    }

    fn save_payload(&self, w: PayloadWriter) -> io::Result<()> {
        persist::save_hnsw(w, self.hnsw)?;
        persist::save_finger(w, self.findex)?;
        // A borrowed view has no mutation state; the v5 TAG_FINGER body
        // still carries a (trivial) live section so it loads uniformly.
        LiveIds::fresh(self.data.rows()).save(w)
    }
}

/// Vamana / DiskANN flat graph.
pub struct VamanaIndex {
    pub data: Arc<Matrix>,
    pub graph: Vamana,
    store: VectorStore,
}

impl VamanaIndex {
    pub fn build(data: Arc<Matrix>, params: VamanaParams) -> VamanaIndex {
        let store = VectorStore::from_matrix(&data);
        let graph = Vamana::build_with_store(&store, params);
        VamanaIndex { data, graph, store }
    }

    pub fn from_parts(data: Arc<Matrix>, graph: Vamana) -> VamanaIndex {
        let store = VectorStore::from_matrix(&data);
        VamanaIndex { data, graph, store }
    }
}

impl AnnIndex for VamanaIndex {
    fn name(&self) -> &'static str {
        "vamana"
    }

    fn dim(&self) -> usize {
        self.data.cols()
    }

    fn len(&self) -> usize {
        self.data.rows()
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn nbytes(&self) -> usize {
        self.graph.adj.nbytes()
    }

    fn search(&self, q: &[f32], params: &SearchParams, ctx: &mut SearchContext) -> Vec<Neighbor> {
        self.graph.search(&self.store, q, params, ctx)
    }

    fn kind_tag(&self) -> u64 {
        persist::TAG_VAMANA
    }

    fn save_payload(&self, w: PayloadWriter) -> io::Result<()> {
        persist::save_vamana(w, &self.graph)
    }
}

/// NN-descent KNN graph.
pub struct NnDescentIndex {
    pub data: Arc<Matrix>,
    pub graph: NnDescent,
    store: VectorStore,
}

impl NnDescentIndex {
    pub fn build(data: Arc<Matrix>, params: NnDescentParams) -> NnDescentIndex {
        let store = VectorStore::from_matrix(&data);
        let graph = NnDescent::build_with_store(&store, params);
        NnDescentIndex { data, graph, store }
    }

    pub fn from_parts(data: Arc<Matrix>, graph: NnDescent) -> NnDescentIndex {
        let store = VectorStore::from_matrix(&data);
        NnDescentIndex { data, graph, store }
    }
}

impl AnnIndex for NnDescentIndex {
    fn name(&self) -> &'static str {
        "nndescent"
    }

    fn dim(&self) -> usize {
        self.data.cols()
    }

    fn len(&self) -> usize {
        self.data.rows()
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn nbytes(&self) -> usize {
        self.graph.adj.nbytes()
    }

    fn search(&self, q: &[f32], params: &SearchParams, ctx: &mut SearchContext) -> Vec<Neighbor> {
        self.graph.search(&self.store, q, params, ctx)
    }

    fn kind_tag(&self) -> u64 {
        persist::TAG_NNDESCENT
    }

    fn save_payload(&self, w: PayloadWriter) -> io::Result<()> {
        persist::save_nndescent(w, &self.graph)
    }
}

/// IVF-PQ with exact re-rank.
pub struct IvfPqIndex {
    pub data: Arc<Matrix>,
    pub quant: IvfPq,
}

impl IvfPqIndex {
    pub fn build(data: Arc<Matrix>, params: IvfPqParams) -> IvfPqIndex {
        let quant = IvfPq::train(&data, params);
        IvfPqIndex { data, quant }
    }

    pub fn from_parts(data: Arc<Matrix>, quant: IvfPq) -> IvfPqIndex {
        IvfPqIndex { data, quant }
    }
}

impl AnnIndex for IvfPqIndex {
    fn name(&self) -> &'static str {
        "ivfpq"
    }

    fn dim(&self) -> usize {
        self.data.cols()
    }

    fn len(&self) -> usize {
        self.data.rows()
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn nbytes(&self) -> usize {
        let q = &self.quant;
        q.coarse.centroids.nbytes()
            + q.lists.iter().map(|l| l.len() * 4).sum::<usize>()
            + q.pq.codes.len()
            + q.pq.books.iter().map(|b| b.centroids.nbytes()).sum::<usize>()
    }

    fn search(&self, q: &[f32], params: &SearchParams, ctx: &mut SearchContext) -> Vec<Neighbor> {
        self.quant.search(&self.data, q, params, ctx)
    }

    fn kind_tag(&self) -> u64 {
        persist::TAG_IVFPQ
    }

    fn save_payload(&self, w: PayloadWriter) -> io::Result<()> {
        persist::save_ivfpq(w, &self.quant)
    }
}
