//! [`AnnIndex`] implementors: one thin wrapper per family pairing a
//! shared data matrix (`Arc<Matrix>` — datasets are shared, not copied,
//! across index variants) with the family's graph/codebook state. The
//! family modules keep their borrowed-data search methods; these wrappers
//! are the self-contained objects the server, sweeps, CLI, and
//! persistence operate on.

use std::io;
use std::sync::Arc;

use crate::core::matrix::Matrix;
use crate::data::io::BinWriter;
use crate::data::persist;
use crate::finger::construct::{FingerIndex, FingerParams};
use crate::finger::search::{search_hnsw_with_index, FingerHnsw};
use crate::graph::bruteforce::scan;
use crate::graph::hnsw::{Hnsw, HnswParams};
use crate::graph::nndescent::{NnDescent, NnDescentParams};
use crate::graph::search::Neighbor;
use crate::graph::vamana::{Vamana, VamanaParams};
use crate::index::context::{SearchContext, SearchParams};
use crate::index::AnnIndex;
use crate::quant::ivfpq::{IvfPq, IvfPqParams};

type PayloadWriter<'a, 'b> = &'a mut BinWriter<&'b mut dyn io::Write>;

/// One small instance of every family over `data` — shared by the
/// persistence-roundtrip and trait-conformance suites (and handy for
/// demos), so a new family is registered in exactly one place.
/// When adding a family here, mirror it in
/// [`crate::index::sharded::build_all_families_sharded`] (and its label
/// match) so the sharded conformance coverage keeps pace.
pub fn build_all_families(data: Arc<Matrix>) -> Vec<Box<dyn AnnIndex>> {
    vec![
        Box::new(BruteForce::new(Arc::clone(&data))),
        Box::new(HnswIndex::build(
            Arc::clone(&data),
            HnswParams { m: 12, ef_construction: 80, ..Default::default() },
        )),
        Box::new(FingerHnswIndex::build(
            Arc::clone(&data),
            HnswParams { m: 12, ef_construction: 80, ..Default::default() },
            FingerParams { rank: 8, ..Default::default() },
        )),
        Box::new(VamanaIndex::build(Arc::clone(&data), VamanaParams::default())),
        Box::new(NnDescentIndex::build(
            Arc::clone(&data),
            NnDescentParams::default(),
        )),
        Box::new(IvfPqIndex::build(
            data,
            IvfPqParams { n_list: 16, ..Default::default() },
        )),
    ]
}

/// Exact linear scan — the reference implementor every other family is
/// conformance-tested against.
pub struct BruteForce {
    pub data: Arc<Matrix>,
}

impl BruteForce {
    pub fn new(data: Arc<Matrix>) -> BruteForce {
        BruteForce { data }
    }
}

impl AnnIndex for BruteForce {
    fn name(&self) -> &'static str {
        "bruteforce"
    }

    fn dim(&self) -> usize {
        self.data.cols()
    }

    fn len(&self) -> usize {
        self.data.rows()
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn nbytes(&self) -> usize {
        0
    }

    fn search(&self, q: &[f32], params: &SearchParams, ctx: &mut SearchContext) -> Vec<Neighbor> {
        if ctx.stats_enabled {
            ctx.stats.dist_calls += self.data.rows() as u64;
        }
        scan(&self.data, q, params.k)
    }

    fn kind_tag(&self) -> u64 {
        persist::TAG_BRUTEFORCE
    }

    fn save_payload(&self, _w: PayloadWriter) -> io::Result<()> {
        Ok(()) // nothing beyond the data matrix
    }
}

/// Plain HNSW (Algorithm 1 search).
pub struct HnswIndex {
    pub data: Arc<Matrix>,
    pub graph: Hnsw,
}

impl HnswIndex {
    pub fn build(data: Arc<Matrix>, params: HnswParams) -> HnswIndex {
        let graph = Hnsw::build(&data, params);
        HnswIndex { data, graph }
    }

    pub fn from_parts(data: Arc<Matrix>, graph: Hnsw) -> HnswIndex {
        HnswIndex { data, graph }
    }
}

impl AnnIndex for HnswIndex {
    fn name(&self) -> &'static str {
        "hnsw"
    }

    fn dim(&self) -> usize {
        self.data.cols()
    }

    fn len(&self) -> usize {
        self.data.rows()
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn nbytes(&self) -> usize {
        self.graph.nbytes()
    }

    fn search(&self, q: &[f32], params: &SearchParams, ctx: &mut SearchContext) -> Vec<Neighbor> {
        self.graph.search(&self.data, q, params, ctx)
    }

    fn kind_tag(&self) -> u64 {
        persist::TAG_HNSW
    }

    fn save_payload(&self, w: PayloadWriter) -> io::Result<()> {
        persist::save_hnsw(w, &self.graph)
    }
}

/// HNSW + FINGER screening (the paper's system).
pub struct FingerHnswIndex {
    pub data: Arc<Matrix>,
    pub inner: FingerHnsw,
}

impl FingerHnswIndex {
    pub fn build(
        data: Arc<Matrix>,
        hnsw_params: HnswParams,
        finger_params: FingerParams,
    ) -> FingerHnswIndex {
        let inner = FingerHnsw::build(&data, hnsw_params, finger_params);
        FingerHnswIndex { data, inner }
    }

    pub fn from_parts(data: Arc<Matrix>, inner: FingerHnsw) -> FingerHnswIndex {
        FingerHnswIndex { data, inner }
    }
}

impl AnnIndex for FingerHnswIndex {
    fn name(&self) -> &'static str {
        "hnsw-finger"
    }

    fn dim(&self) -> usize {
        self.data.cols()
    }

    fn len(&self) -> usize {
        self.data.rows()
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn nbytes(&self) -> usize {
        self.inner.nbytes()
    }

    fn approx_rank(&self) -> usize {
        self.inner.index.rank
    }

    fn search(&self, q: &[f32], params: &SearchParams, ctx: &mut SearchContext) -> Vec<Neighbor> {
        self.inner.search(&self.data, q, params, ctx)
    }

    fn kind_tag(&self) -> u64 {
        persist::TAG_FINGER
    }

    fn save_payload(&self, w: PayloadWriter) -> io::Result<()> {
        persist::save_hnsw(w, &self.inner.hnsw)?;
        persist::save_finger(w, &self.inner.index)
    }
}

/// Borrowing FINGER adapter: one shared HNSW graph, many FINGER/RPLSH
/// side-index variants — the Figure 6 ablation shape. Searchable through
/// `&dyn AnnIndex` like everything else, without moving the graph.
pub struct FingerView<'a> {
    pub data: &'a Matrix,
    pub hnsw: &'a Hnsw,
    pub findex: &'a FingerIndex,
    /// Label shown by sweeps ("finger", "rplsh", ...).
    pub label: &'static str,
}

impl AnnIndex for FingerView<'_> {
    fn name(&self) -> &'static str {
        self.label
    }

    fn dim(&self) -> usize {
        self.data.cols()
    }

    fn len(&self) -> usize {
        self.data.rows()
    }

    fn data(&self) -> &Matrix {
        self.data
    }

    fn nbytes(&self) -> usize {
        self.hnsw.nbytes() + self.findex.nbytes()
    }

    fn approx_rank(&self) -> usize {
        self.findex.rank
    }

    fn search(&self, q: &[f32], params: &SearchParams, ctx: &mut SearchContext) -> Vec<Neighbor> {
        search_hnsw_with_index(self.hnsw, self.findex, self.data, q, params, ctx)
    }

    fn kind_tag(&self) -> u64 {
        persist::TAG_FINGER
    }

    fn save_payload(&self, w: PayloadWriter) -> io::Result<()> {
        persist::save_hnsw(w, self.hnsw)?;
        persist::save_finger(w, self.findex)
    }
}

/// Vamana / DiskANN flat graph.
pub struct VamanaIndex {
    pub data: Arc<Matrix>,
    pub graph: Vamana,
}

impl VamanaIndex {
    pub fn build(data: Arc<Matrix>, params: VamanaParams) -> VamanaIndex {
        let graph = Vamana::build(&data, params);
        VamanaIndex { data, graph }
    }

    pub fn from_parts(data: Arc<Matrix>, graph: Vamana) -> VamanaIndex {
        VamanaIndex { data, graph }
    }
}

impl AnnIndex for VamanaIndex {
    fn name(&self) -> &'static str {
        "vamana"
    }

    fn dim(&self) -> usize {
        self.data.cols()
    }

    fn len(&self) -> usize {
        self.data.rows()
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn nbytes(&self) -> usize {
        self.graph.adj.nbytes()
    }

    fn search(&self, q: &[f32], params: &SearchParams, ctx: &mut SearchContext) -> Vec<Neighbor> {
        self.graph.search(&self.data, q, params, ctx)
    }

    fn kind_tag(&self) -> u64 {
        persist::TAG_VAMANA
    }

    fn save_payload(&self, w: PayloadWriter) -> io::Result<()> {
        persist::save_vamana(w, &self.graph)
    }
}

/// NN-descent KNN graph.
pub struct NnDescentIndex {
    pub data: Arc<Matrix>,
    pub graph: NnDescent,
}

impl NnDescentIndex {
    pub fn build(data: Arc<Matrix>, params: NnDescentParams) -> NnDescentIndex {
        let graph = NnDescent::build(&data, params);
        NnDescentIndex { data, graph }
    }

    pub fn from_parts(data: Arc<Matrix>, graph: NnDescent) -> NnDescentIndex {
        NnDescentIndex { data, graph }
    }
}

impl AnnIndex for NnDescentIndex {
    fn name(&self) -> &'static str {
        "nndescent"
    }

    fn dim(&self) -> usize {
        self.data.cols()
    }

    fn len(&self) -> usize {
        self.data.rows()
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn nbytes(&self) -> usize {
        self.graph.adj.nbytes()
    }

    fn search(&self, q: &[f32], params: &SearchParams, ctx: &mut SearchContext) -> Vec<Neighbor> {
        self.graph.search(&self.data, q, params, ctx)
    }

    fn kind_tag(&self) -> u64 {
        persist::TAG_NNDESCENT
    }

    fn save_payload(&self, w: PayloadWriter) -> io::Result<()> {
        persist::save_nndescent(w, &self.graph)
    }
}

/// IVF-PQ with exact re-rank.
pub struct IvfPqIndex {
    pub data: Arc<Matrix>,
    pub quant: IvfPq,
}

impl IvfPqIndex {
    pub fn build(data: Arc<Matrix>, params: IvfPqParams) -> IvfPqIndex {
        let quant = IvfPq::train(&data, params);
        IvfPqIndex { data, quant }
    }

    pub fn from_parts(data: Arc<Matrix>, quant: IvfPq) -> IvfPqIndex {
        IvfPqIndex { data, quant }
    }
}

impl AnnIndex for IvfPqIndex {
    fn name(&self) -> &'static str {
        "ivfpq"
    }

    fn dim(&self) -> usize {
        self.data.cols()
    }

    fn len(&self) -> usize {
        self.data.rows()
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn nbytes(&self) -> usize {
        let q = &self.quant;
        q.coarse.centroids.nbytes()
            + q.lists.iter().map(|l| l.len() * 4).sum::<usize>()
            + q.pq.codes.len()
            + q.pq.books.iter().map(|b| b.centroids.nbytes()).sum::<usize>()
    }

    fn search(&self, q: &[f32], params: &SearchParams, ctx: &mut SearchContext) -> Vec<Neighbor> {
        self.quant.search(&self.data, q, params, ctx)
    }

    fn kind_tag(&self) -> u64 {
        persist::TAG_IVFPQ
    }

    fn save_payload(&self, w: PayloadWriter) -> io::Result<()> {
        persist::save_ivfpq(w, &self.quant)
    }
}
