//! Scatter-gather result merging for [`crate::index::sharded::ShardedIndex`].
//!
//! Each shard returns its local top-k ascending by `Neighbor`'s total
//! order (distance, then id). After local→global id remapping the lists
//! stay sorted — shard membership is recorded in ascending global-id
//! order, so the remap is monotone — and a k-way streaming merge yields
//! the global top-k without materializing the full union. Because global
//! ids are unique across shards, the merged order is exactly the
//! brute-force total order over the union, ties included (proven in
//! `rust/tests/shard_props.rs`).

use std::collections::BinaryHeap;

use crate::graph::search::{MinNeighbor, Neighbor};

/// Rewrite shard-local ids to global ids in place. `global_ids[local]`
/// must be the global row id of the shard's local row `local`.
pub fn remap_to_global(res: &mut [Neighbor], global_ids: &[u32]) {
    for n in res.iter_mut() {
        n.id = global_ids[n.id as usize];
    }
}

/// Streaming k-way merge of ascending per-shard result lists into the
/// global top-`k`, ascending by (distance, id). Pops one head at a time
/// from a heap of list cursors, so cost is O(k log S) after the heap is
/// seeded — it never sorts the whole union.
pub fn merge_topk(lists: &[Vec<Neighbor>], k: usize) -> Vec<Neighbor> {
    // Heap entries are (head, list index); `MinNeighbor` flips the max-heap
    // so the smallest (dist, id) pops first. The list index only breaks
    // exact (dist, id) duplicates, which cannot occur for distinct points.
    let mut heap: BinaryHeap<(MinNeighbor, usize)> = BinaryHeap::with_capacity(lists.len());
    let mut cursor = vec![0usize; lists.len()];
    for (li, list) in lists.iter().enumerate() {
        if let Some(&head) = list.first() {
            heap.push((MinNeighbor(head), li));
            cursor[li] = 1;
        }
    }
    let total: usize = lists.iter().map(|l| l.len()).sum();
    let mut out = Vec::with_capacity(k.min(total));
    while out.len() < k {
        let Some((MinNeighbor(nb), li)) = heap.pop() else {
            break;
        };
        out.push(nb);
        if cursor[li] < lists[li].len() {
            heap.push((MinNeighbor(lists[li][cursor[li]]), li));
            cursor[li] += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nb(dist: f32, id: u32) -> Neighbor {
        Neighbor { dist, id }
    }

    #[test]
    fn merges_sorted_lists_ascending() {
        let lists = vec![
            vec![nb(0.1, 3), nb(0.5, 1), nb(2.0, 9)],
            vec![nb(0.2, 4), nb(0.3, 7)],
            vec![nb(1.0, 0)],
        ];
        let got = merge_topk(&lists, 4);
        let ids: Vec<u32> = got.iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![3, 4, 7, 1]);
        for w in got.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn ties_break_by_global_id() {
        let lists = vec![
            vec![nb(1.0, 5), nb(1.0, 8)],
            vec![nb(1.0, 2), nb(1.0, 6)],
        ];
        let ids: Vec<u32> = merge_topk(&lists, 3).iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![2, 5, 6]);
    }

    #[test]
    fn k_beyond_total_returns_everything() {
        let lists = vec![vec![nb(0.5, 1)], Vec::new(), vec![nb(0.2, 2)]];
        let got = merge_topk(&lists, 10);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].id, 2);
    }

    #[test]
    fn empty_input_is_empty() {
        assert!(merge_topk(&[], 5).is_empty());
        assert!(merge_topk(&[Vec::new(), Vec::new()], 5).is_empty());
        assert!(merge_topk(&[vec![nb(1.0, 1)]], 0).is_empty());
    }

    #[test]
    fn remap_rewrites_local_ids() {
        let mut res = vec![nb(0.1, 0), nb(0.2, 2), nb(0.3, 1)];
        remap_to_global(&mut res, &[10, 20, 30]);
        let ids: Vec<u32> = res.iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![10, 30, 20]);
    }
}
