//! The mutation plane: incremental insert, tombstone delete, and
//! threshold-gated compaction behind the [`AnnIndex`] families that can
//! support them (FreshDiskANN-style update scheme, scaled to this repo).
//!
//! Identity is external: every point carries a stable **external id**
//! assigned at insert time from a monotone watermark ([`LiveIds::next_id`]).
//! Searches emit external ids, deletes address external ids, and
//! compaction — which drops tombstoned rows and rebuilds the graph over
//! the survivors — never renumbers anything a client has seen. Internally
//! each index keeps a dense row space (`row_ids[row] = external id`,
//! strictly ascending, so the row→external remap is monotone and
//! preserves the `(distance, id)` result order that the shard merge and
//! the brute-force oracle agree on).
//!
//! Deletes are tombstones: a bitset consulted when *emitting* results but
//! not when traversing the graph, so connectivity through deleted nodes
//! survives (see `graph::search::beam_search_live`). `compact()` rebuilds
//! once the tombstone fraction crosses a threshold; the FINGER family
//! re-trains its residual bases on the live set when it does.
//!
//! Implementors keep their padded query-time
//! [`VectorStore`](crate::core::store::VectorStore) in lockstep with the
//! data matrix: inserts push the row into both, compaction rebuilds the
//! store from the gathered survivors — so the mutable search paths score
//! against the same aligned, tail-free rows as the static ones.

use std::fmt;
use std::io;

use crate::data::io::{BinReader, BinWriter};
use crate::graph::search::Neighbor;
use crate::index::context::SearchContext;
use crate::index::AnnIndex;

/// Default tombstone fraction above which `compact()` rebuilds.
pub const DEFAULT_COMPACT_THRESHOLD: f64 = 0.3;

/// Why a mutation was rejected. Mutations never panic on bad input —
/// unsupported families and stale ids report structured errors instead.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MutateError {
    /// The index family does not implement the mutation plane.
    Unsupported(&'static str),
    /// Inserted vector has the wrong dimensionality.
    DimMismatch { got: usize, want: usize },
    /// No live or tombstoned point carries this external id (never
    /// assigned, or reclaimed by compaction).
    UnknownId(u32),
    /// The id exists but was already tombstoned.
    AlreadyDeleted(u32),
}

impl fmt::Display for MutateError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MutateError::Unsupported(name) => {
                write!(f, "index family '{name}' does not support mutation")
            }
            MutateError::DimMismatch { got, want } => {
                write!(f, "vector dim mismatch: got {got}, want {want}")
            }
            MutateError::UnknownId(id) => write!(f, "unknown id {id}"),
            MutateError::AlreadyDeleted(id) => write!(f, "id {id} already deleted"),
        }
    }
}

impl std::error::Error for MutateError {}

/// Extension trait for index families that serve a live, churning corpus.
///
/// Obtain it through [`AnnIndex::as_mutable`] (families that cannot
/// mutate return `None` — cleanly unsupported, never a panic). All
/// methods keep the implementor consistent with its [`AnnIndex`] view:
/// after any interleaving of calls, `search` over the live set equals
/// brute force over the live set (proven in `rust/tests/mutation_props.rs`).
pub trait MutableAnnIndex: AnnIndex {
    /// Add a vector; returns its permanent external id. `ctx` is search
    /// scratch for the incremental graph insertion.
    fn insert(&mut self, v: &[f32], ctx: &mut SearchContext) -> Result<u32, MutateError>;

    /// Tombstone an external id. The point stops being emitted
    /// immediately; its graph node keeps routing until `compact()`.
    fn remove(&mut self, id: u32) -> Result<(), MutateError>;

    /// Rebuild over the live set if the tombstone fraction has crossed
    /// the compaction threshold. Returns whether a rebuild happened.
    /// External ids and the watermark survive compaction.
    fn compact(&mut self, ctx: &mut SearchContext) -> Result<bool, MutateError>;

    /// Number of live (non-tombstoned) points.
    fn live_len(&self) -> usize;

    /// Is this external id currently live?
    fn is_live(&self, id: u32) -> bool;

    /// All live external ids, ascending.
    fn live_ids(&self) -> Vec<u32>;

    /// Tombstoned fraction of the stored rows (0 when empty).
    fn tombstone_fraction(&self) -> f64;

    /// Set the tombstone fraction at which `compact()` rebuilds
    /// (composite indexes forward it to their sub-indexes).
    fn set_compact_threshold(&mut self, frac: f64);

    /// The currently effective compaction threshold. Checkpointing logs
    /// a non-default value into the fresh generation (`SetThreshold`) so
    /// replay and replica apply gate compaction at the log-time value.
    fn compact_threshold(&self) -> f64;
}

/// External-id bookkeeping shared by every mutable family: the
/// row→external map, the tombstone bitset, and the next-id watermark.
///
/// Invariants (enforced at load, maintained by construction):
/// `row_ids` is strictly ascending, every entry is `< next_id`, and the
/// bitset covers exactly the rows.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LiveIds {
    /// `row_ids[row]` = external id of that row; strictly ascending.
    row_ids: Vec<u32>,
    /// Tombstone bitset over rows (1 = deleted).
    bits: Vec<u64>,
    n_dead: usize,
    /// Watermark: the next external id `alloc` hands out. Monotone for
    /// the lifetime of the index, including across compactions.
    next_id: u32,
}

impl LiveIds {
    /// Identity mapping over `n` freshly built rows (ids `0..n`).
    pub fn fresh(n: usize) -> LiveIds {
        LiveIds {
            row_ids: (0..n as u32).collect(),
            bits: vec![0u64; n.div_ceil(64)],
            n_dead: 0,
            next_id: n as u32,
        }
    }

    /// Reassemble from persisted parts (validated by the caller; see
    /// [`LiveIds::load`]).
    fn from_parts(row_ids: Vec<u32>, dead_rows: &[u32], next_id: u32) -> LiveIds {
        let mut live = LiveIds {
            bits: vec![0u64; row_ids.len().div_ceil(64)],
            row_ids,
            n_dead: 0,
            next_id,
        };
        for &d in dead_rows {
            live.kill_row(d as usize);
        }
        live
    }

    pub fn n_rows(&self) -> usize {
        self.row_ids.len()
    }

    pub fn live_len(&self) -> usize {
        self.row_ids.len() - self.n_dead
    }

    pub fn n_dead(&self) -> usize {
        self.n_dead
    }

    pub fn next_id(&self) -> u32 {
        self.next_id
    }

    pub fn any_dead(&self) -> bool {
        self.n_dead > 0
    }

    /// True while external ids coincide with row ids and nothing is
    /// tombstoned — the fast path where mutated-index searches reduce to
    /// the plain static ones.
    pub fn is_identity(&self) -> bool {
        self.n_dead == 0 && self.next_id as usize == self.row_ids.len()
    }

    pub fn tombstone_fraction(&self) -> f64 {
        if self.row_ids.is_empty() {
            0.0
        } else {
            self.n_dead as f64 / self.row_ids.len() as f64
        }
    }

    /// Has the tombstone fraction crossed `threshold` (and is there
    /// anything to reclaim)?
    pub fn should_compact(&self, threshold: f64) -> bool {
        self.n_dead > 0 && self.tombstone_fraction() >= threshold
    }

    #[inline]
    pub fn is_dead_row(&self, row: usize) -> bool {
        (self.bits[row >> 6] >> (row & 63)) & 1 == 1
    }

    #[inline]
    pub fn external_of(&self, row: usize) -> u32 {
        self.row_ids[row]
    }

    /// Row currently holding external id `id` (live or tombstoned);
    /// `None` if the id was never assigned or was reclaimed by a
    /// compaction. Binary search — `row_ids` is strictly ascending.
    pub fn row_of(&self, id: u32) -> Option<usize> {
        self.row_ids.binary_search(&id).ok()
    }

    pub fn is_live(&self, id: u32) -> bool {
        self.row_of(id).is_some_and(|row| !self.is_dead_row(row))
    }

    /// All live external ids, ascending.
    pub fn live_ids(&self) -> Vec<u32> {
        (0..self.row_ids.len())
            .filter(|&row| !self.is_dead_row(row))
            .map(|row| self.row_ids[row])
            .collect()
    }

    /// Register a newly appended row; returns its external id (the
    /// watermark value).
    pub fn alloc(&mut self) -> u32 {
        let id = self.next_id;
        self.row_ids.push(id);
        self.next_id += 1;
        if self.row_ids.len() > self.bits.len() * 64 {
            self.bits.push(0);
        }
        id
    }

    /// Tombstone a row. Returns false if it was already dead.
    pub fn kill_row(&mut self, row: usize) -> bool {
        if self.is_dead_row(row) {
            return false;
        }
        self.bits[row >> 6] |= 1u64 << (row & 63);
        self.n_dead += 1;
        true
    }

    /// Rows that survive a compaction, ascending.
    pub fn compact_plan(&self) -> Vec<usize> {
        (0..self.row_ids.len())
            .filter(|&row| !self.is_dead_row(row))
            .collect()
    }

    /// Drop tombstoned rows from the map (the caller rebuilds its data /
    /// graph over `compact_plan()` in the same order). The watermark is
    /// untouched, so reclaimed ids are never reissued.
    pub fn apply_compact(&mut self) {
        let keep = self.compact_plan();
        self.row_ids = keep.iter().map(|&row| self.row_ids[row]).collect();
        self.bits = vec![0u64; self.row_ids.len().div_ceil(64)];
        self.n_dead = 0;
    }

    /// Rewrite beam-search row ids to external ids in place. Monotone
    /// (`row_ids` ascending), so ascending `(dist, id)` order survives.
    pub fn remap_rows_to_external(&self, res: &mut [Neighbor]) {
        for n in res.iter_mut() {
            n.id = self.row_ids[n.id as usize];
        }
    }

    // ------------------------------------------------- persistence (v5)

    /// Serialize the mutation section (format v5): watermark, row→external
    /// map, tombstoned row list.
    pub fn save(&self, w: &mut BinWriter<&mut dyn io::Write>) -> io::Result<()> {
        w.u64(self.next_id as u64)?;
        w.u32_slice(&self.row_ids)?;
        let dead: Vec<u32> = (0..self.row_ids.len() as u32)
            .filter(|&row| self.is_dead_row(row as usize))
            .collect();
        w.u32_slice(&dead)
    }

    /// Read + validate a mutation section written by [`LiveIds::save`].
    /// `n_rows` is the data-matrix row count the section must cover.
    /// Corrupt or truncated sections fail with `InvalidData`/EOF errors,
    /// never a panic.
    pub fn load<R: io::Read>(r: &mut BinReader<R>, n_rows: usize) -> io::Result<LiveIds> {
        let invalid = |msg: &str| io::Error::new(io::ErrorKind::InvalidData, msg.to_string());
        let next_id = r.u64()?;
        if next_id > u32::MAX as u64 {
            return Err(invalid("implausible id watermark"));
        }
        let row_ids = r.u32_slice()?;
        if row_ids.len() != n_rows {
            return Err(invalid("row-id map does not cover the data matrix"));
        }
        if row_ids.windows(2).any(|w| w[0] >= w[1]) {
            return Err(invalid("row-id map not strictly ascending"));
        }
        if row_ids.iter().any(|&id| id as u64 >= next_id) {
            return Err(invalid("row id at or above the watermark"));
        }
        let dead = r.u32_slice()?;
        if dead.windows(2).any(|w| w[0] >= w[1]) {
            return Err(invalid("tombstone list not strictly ascending"));
        }
        if dead.iter().any(|&d| d as usize >= n_rows) {
            return Err(invalid("tombstoned row out of range"));
        }
        Ok(LiveIds::from_parts(row_ids, &dead, next_id as u32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_is_identity() {
        let live = LiveIds::fresh(5);
        assert!(live.is_identity());
        assert_eq!(live.live_len(), 5);
        assert_eq!(live.next_id(), 5);
        assert_eq!(live.live_ids(), vec![0, 1, 2, 3, 4]);
        assert!(!live.any_dead());
        assert_eq!(live.tombstone_fraction(), 0.0);
    }

    #[test]
    fn alloc_kill_compact_lifecycle() {
        let mut live = LiveIds::fresh(3);
        assert_eq!(live.alloc(), 3);
        assert_eq!(live.alloc(), 4);
        assert!(live.kill_row(1));
        assert!(!live.kill_row(1), "double kill reports false");
        assert!(live.kill_row(3));
        assert_eq!(live.live_len(), 3);
        assert_eq!(live.live_ids(), vec![0, 2, 4]);
        assert!(!live.is_live(1));
        assert!(live.is_live(4));
        assert!((live.tombstone_fraction() - 0.4).abs() < 1e-12);
        assert!(live.should_compact(0.4));
        assert!(!live.should_compact(0.5));

        live.apply_compact();
        assert_eq!(live.n_rows(), 3);
        assert_eq!(live.live_ids(), vec![0, 2, 4]);
        assert_eq!(live.next_id(), 5, "watermark survives compaction");
        assert!(!live.is_identity(), "external ids keep their holes");
        assert_eq!(live.row_of(2), Some(1));
        assert_eq!(live.row_of(1), None, "reclaimed id is unknown");
        assert_eq!(live.alloc(), 5, "reclaimed ids are never reissued");
    }

    #[test]
    fn remap_is_monotone() {
        let mut live = LiveIds::fresh(4);
        live.kill_row(1);
        live.apply_compact(); // rows now map to ids [0, 2, 3]
        let mut res = vec![
            Neighbor { dist: 0.1, id: 0 },
            Neighbor { dist: 0.2, id: 1 },
            Neighbor { dist: 0.2, id: 2 },
        ];
        live.remap_rows_to_external(&mut res);
        let ids: Vec<u32> = res.iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![0, 2, 3]);
        assert!(res.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn save_load_roundtrip_and_rejection() {
        let mut live = LiveIds::fresh(6);
        live.alloc();
        live.kill_row(2);
        live.kill_row(5);

        let mut buf = Vec::new();
        {
            let sink: &mut dyn io::Write = &mut buf;
            let mut w = BinWriter::new(sink);
            live.save(&mut w).unwrap();
        }
        let mut r = BinReader::new(&buf[..]);
        let back = LiveIds::load(&mut r, 7).unwrap();
        assert_eq!(back, live);

        // Wrong row count rejected.
        let mut r = BinReader::new(&buf[..]);
        assert!(LiveIds::load(&mut r, 9).is_err());

        // Truncation rejected with an error, not a panic.
        let mut r = BinReader::new(&buf[..buf.len() - 3]);
        assert!(LiveIds::load(&mut r, 7).is_err());

        // Out-of-range tombstone rejected (last 4 bytes are the final
        // dead-row entry).
        let mut corrupt = buf.clone();
        let n = corrupt.len();
        corrupt[n - 4..].copy_from_slice(&999u32.to_le_bytes());
        let mut r = BinReader::new(&corrupt[..]);
        assert!(LiveIds::load(&mut r, 7).is_err());
    }

    #[test]
    fn mutate_error_messages() {
        assert!(MutateError::Unsupported("ivfpq").to_string().contains("ivfpq"));
        assert!(MutateError::DimMismatch { got: 3, want: 8 }.to_string().contains("3"));
        assert!(MutateError::UnknownId(7).to_string().contains('7'));
        assert!(MutateError::AlreadyDeleted(9).to_string().contains('9'));
    }
}
