//! Miniature property-testing harness (the offline environment carries no
//! proptest). `forall` runs a closure over `n` seeded random cases and
//! reports the first failing seed; failures are reproducible by
//! construction because all generators take the seed explicitly.
//!
//! [`proxy`] adds a fault-injecting TCP proxy for replication tests.

pub mod proxy;

use crate::core::rng::Pcg32;

/// Run `f` over `cases` deterministic seeds. On panic or `false`, panics
/// with the failing seed so the case can be replayed.
pub fn forall(name: &str, cases: u64, f: impl Fn(&mut Pcg32) -> bool) {
    for case in 0..cases {
        let seed = 0x9E37_79B9 ^ (case * 0x1000_0001);
        let mut rng = Pcg32::new(seed);
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        match ok {
            Ok(true) => {}
            Ok(false) => panic!("property '{name}' failed at case {case} (seed {seed:#x})"),
            Err(e) => panic!(
                "property '{name}' panicked at case {case} (seed {seed:#x}): {:?}",
                e.downcast_ref::<&str>()
            ),
        }
    }
}

/// Random f32 vector generator.
pub fn vec_f32(rng: &mut Pcg32, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.next_gaussian()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("addition commutes", 20, |rng| {
            let a = rng.next_f32();
            let b = rng.next_f32();
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn failing_property_reports_seed() {
        forall("always false", 3, |_| false);
    }
}
