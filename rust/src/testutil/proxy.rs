//! Fault-injecting TCP proxy for the replication stream.
//!
//! Sits between a replica and its primary. The replica→primary direction
//! (Hello + Acks) is forwarded verbatim; the primary→replica direction is
//! parsed at frame granularity (the 9-byte `crc|len|type` header from
//! [`crate::repl::frame`]) and each frame runs through a seeded fault
//! plan:
//!
//! * **Drop** — the frame vanishes; later frames keep flowing, so the
//!   replica sees a sequence gap it must detect itself.
//! * **Duplicate** — the frame is written twice; the replica must reject
//!   the replay.
//! * **Delay** — the frame is held briefly, bunching deliveries.
//! * **Truncate** — a prefix of the frame is written and the connection
//!   is cut: a torn frame, exactly what a mid-write crash produces.
//!
//! The accept loop keeps serving, so a replica that drops a poisoned
//! connection reconnects *through the proxy* and keeps getting faults
//! until the plan's budget is spent. Faults are deterministic in the
//! seed — a failing schedule replays exactly.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::core::rng::Pcg32;
use crate::repl::frame::HEADER_SIZE;

/// What the plan decided for one downstream frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    Forward,
    Drop,
    Duplicate,
    Delay,
    /// Write only a prefix of the frame, then cut the connection.
    Truncate,
}

/// Seeded per-frame fault decisions with a bounded budget: after
/// `max_faults` injections every frame forwards cleanly, so the system
/// under test always gets a fault-free tail to converge on.
pub struct FaultPlan {
    rng: Pcg32,
    /// Chance (out of 100) that any one frame draws a fault.
    pub fault_pct: u32,
    pub max_faults: u64,
    injected: u64,
}

impl FaultPlan {
    pub fn new(seed: u64, fault_pct: u32, max_faults: u64) -> FaultPlan {
        FaultPlan { rng: Pcg32::new(seed), fault_pct, max_faults, injected: 0 }
    }

    fn decide(&mut self) -> Fault {
        if self.injected >= self.max_faults
            || self.rng.gen_range(100) >= self.fault_pct as usize
        {
            return Fault::Forward;
        }
        self.injected += 1;
        match self.rng.gen_range(4) {
            0 => Fault::Drop,
            1 => Fault::Duplicate,
            2 => Fault::Delay,
            _ => Fault::Truncate,
        }
    }
}

/// A running fault proxy. One upstream (the primary's replication
/// listener), one listening socket replicas point at.
pub struct FaultProxy {
    pub local_addr: SocketAddr,
    injected: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl FaultProxy {
    /// Listen on an ephemeral port and relay every accepted connection to
    /// `upstream`, faulting primary→replica frames per the plan. The plan
    /// is shared across reconnects (one budget for the proxy's lifetime).
    pub fn start(upstream: SocketAddr, plan: FaultPlan) -> std::io::Result<FaultProxy> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let injected = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let thread = {
            let injected = Arc::clone(&injected);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new().name("fault-proxy".into()).spawn(move || {
                // The plan lives on the accept thread; connections are
                // served one at a time (replication uses one connection,
                // and serialized service keeps fault order deterministic).
                let mut plan = plan;
                loop {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((client, _)) => {
                            relay(client, upstream, &mut plan, &injected, &stop);
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?
        };
        Ok(FaultProxy { local_addr, injected, stop: Arc::clone(&stop), thread: Some(thread) })
    }

    /// Faults injected so far (proves the plan actually fired).
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Serve one proxied connection until either side closes or a Truncate
/// fault cuts it.
fn relay(
    client: TcpStream,
    upstream: SocketAddr,
    plan: &mut FaultPlan,
    injected: &AtomicU64,
    stop: &AtomicBool,
) {
    let Ok(server) = TcpStream::connect_timeout(&upstream, Duration::from_millis(500)) else {
        return;
    };
    client.set_nodelay(true).ok();
    server.set_nodelay(true).ok();

    // Upstream direction (replica → primary): verbatim byte pump.
    let up = {
        let (Ok(mut from), Ok(mut to)) = (client.try_clone(), server.try_clone()) else {
            return;
        };
        std::thread::spawn(move || {
            let mut buf = [0u8; 4096];
            loop {
                match from.read(&mut buf) {
                    Ok(0) | Err(_) => break,
                    Ok(n) => {
                        if to.write_all(&buf[..n]).is_err() {
                            break;
                        }
                    }
                }
            }
            to.shutdown(std::net::Shutdown::Write).ok();
        })
    };

    // Downstream direction (primary → replica): frame-by-frame faults.
    let mut from = server;
    let mut to = client;
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let Some(frame) = read_raw_frame(&mut from) else { break };
        match plan.decide() {
            Fault::Forward => {
                if to.write_all(&frame).is_err() {
                    break;
                }
            }
            Fault::Drop => {
                injected.fetch_add(1, Ordering::Relaxed);
            }
            Fault::Duplicate => {
                injected.fetch_add(1, Ordering::Relaxed);
                if to.write_all(&frame).is_err() || to.write_all(&frame).is_err() {
                    break;
                }
            }
            Fault::Delay => {
                injected.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(20));
                if to.write_all(&frame).is_err() {
                    break;
                }
            }
            Fault::Truncate => {
                injected.fetch_add(1, Ordering::Relaxed);
                let cut = (frame.len() / 2).max(1);
                let _ = to.write_all(&frame[..cut]);
                break;
            }
        }
    }
    // Cut both sides so the replica reconnects promptly.
    to.shutdown(std::net::Shutdown::Both).ok();
    from.shutdown(std::net::Shutdown::Both).ok();
    let _ = up.join();
}

/// Read one whole frame (header + payload) as raw bytes, without
/// validating the CRC — the proxy relays damage, it does not repair it.
fn read_raw_frame(r: &mut TcpStream) -> Option<Vec<u8>> {
    let mut header = [0u8; HEADER_SIZE];
    let mut got = 0;
    while got < HEADER_SIZE {
        match r.read(&mut header[got..]) {
            Ok(0) | Err(_) => return None,
            Ok(n) => got += n,
        }
    }
    let len = u32::from_le_bytes(header[4..8].try_into().unwrap()) as usize;
    let mut frame = header.to_vec();
    frame.resize(HEADER_SIZE + len, 0);
    let mut got = 0;
    while got < len {
        match r.read(&mut frame[HEADER_SIZE + got..]) {
            Ok(0) | Err(_) => return None,
            Ok(n) => got += n,
        }
    }
    Some(frame)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_is_deterministic_and_budgeted() {
        let decisions = |seed: u64| -> Vec<Fault> {
            let mut p = FaultPlan::new(seed, 50, 5);
            (0..100).map(|_| p.decide()).collect()
        };
        assert_eq!(decisions(7), decisions(7), "same seed, same schedule");
        let d = decisions(7);
        let faults = d.iter().filter(|f| **f != Fault::Forward).count();
        assert_eq!(faults, 5, "budget caps injections");
        assert!(
            d.iter().rev().take(50).all(|f| *f == Fault::Forward),
            "after the budget, everything forwards"
        );
    }

    /// The proxy relays a framed stream faithfully when the plan injects
    /// nothing (0% fault chance).
    #[test]
    fn clean_plan_relays_frames_verbatim() {
        use crate::repl::frame::Frame;
        let upstream = TcpListener::bind("127.0.0.1:0").unwrap();
        let up_addr = upstream.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut s, _) = upstream.accept().unwrap();
            // Read the client's hello bytes (upstream pump), then answer
            // with two frames.
            let mut b = [0u8; 1];
            s.read_exact(&mut b).unwrap();
            Frame::Ack { seq: 1 }.write_to(&mut s).unwrap();
            Frame::CaughtUp { seq: 1 }.write_to(&mut s).unwrap();
        });
        let proxy = FaultProxy::start(up_addr, FaultPlan::new(1, 0, 0)).unwrap();
        let mut c = TcpStream::connect(proxy.local_addr).unwrap();
        c.write_all(&[0x55]).unwrap();
        let mut reader = std::io::BufReader::new(c.try_clone().unwrap());
        assert_eq!(Frame::read_from(&mut reader).unwrap(), Some(Frame::Ack { seq: 1 }));
        assert_eq!(
            Frame::read_from(&mut reader).unwrap(),
            Some(Frame::CaughtUp { seq: 1 })
        );
        assert_eq!(proxy.injected(), 0);
        server.join().unwrap();
        proxy.stop();
    }
}
