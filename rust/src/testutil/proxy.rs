//! Fault-injecting TCP proxy for the replication stream.
//!
//! Sits between a follower and its leader. Both directions are parsed at
//! frame granularity (the 9-byte `crc|len|type` header from
//! [`crate::repl::frame`]); the leader→follower direction runs each
//! frame through a seeded fault plan:
//!
//! * **Drop** — the frame vanishes; later frames keep flowing, so the
//!   follower sees a sequence gap it must detect itself.
//! * **Duplicate** — the frame is written twice; the follower must
//!   reject the replay.
//! * **Delay** — the frame is held briefly, bunching deliveries.
//! * **Truncate** — a prefix of the frame is written and the connection
//!   is cut: a torn frame, exactly what a mid-write crash produces.
//! * **Partition** — a symmetric network split: the next few frames are
//!   dropped in *both* directions, then the connection is cut. Unlike
//!   `Drop`, the leader's acks vanish too — this is what makes the
//!   flapping-partition failover tests honest (each side sees the other
//!   go silent, not a one-way loss).
//!
//! The accept loop serves each connection on its own thread (a leader's
//! proxy may front several followers at once), all drawing from one
//! shared plan, so a replica that drops a poisoned connection reconnects
//! *through the proxy* and keeps getting faults until the plan's budget
//! is spent. Faults are deterministic in the seed — a failing schedule
//! replays exactly.

use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::core::rng::Pcg32;
use crate::repl::frame::HEADER_SIZE;

/// Frames dropped per direction when a `Partition` fault fires, before
/// the connection is cut.
pub const PARTITION_FRAMES: u64 = 4;

/// What the plan decided for one downstream frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    Forward,
    Drop,
    Duplicate,
    Delay,
    /// Write only a prefix of the frame, then cut the connection.
    Truncate,
    /// Drop the next [`PARTITION_FRAMES`] frames in both directions,
    /// then cut the connection — a symmetric network split.
    Partition,
}

/// Seeded per-frame fault decisions with a bounded budget: after
/// `max_faults` injections every frame forwards cleanly, so the system
/// under test always gets a fault-free tail to converge on.
pub struct FaultPlan {
    rng: Pcg32,
    /// Chance (out of 100) that any one frame draws a fault.
    pub fault_pct: u32,
    pub max_faults: u64,
    injected: u64,
    /// Every injected fault is a `Partition` (see `partitions_only`).
    partition_only: bool,
}

impl FaultPlan {
    pub fn new(seed: u64, fault_pct: u32, max_faults: u64) -> FaultPlan {
        FaultPlan {
            rng: Pcg32::new(seed),
            fault_pct,
            max_faults,
            injected: 0,
            partition_only: false,
        }
    }

    /// A plan that only ever injects symmetric partitions — the shape
    /// the failover convergence tests want (no torn frames muddying the
    /// signal, just links going dark and coming back).
    pub fn partitions_only(seed: u64, fault_pct: u32, max_faults: u64) -> FaultPlan {
        let mut p = FaultPlan::new(seed, fault_pct, max_faults);
        p.partition_only = true;
        p
    }

    fn decide(&mut self) -> Fault {
        if self.injected >= self.max_faults
            || self.rng.gen_range(100) >= self.fault_pct as usize
        {
            return Fault::Forward;
        }
        self.injected += 1;
        if self.partition_only {
            return Fault::Partition;
        }
        match self.rng.gen_range(5) {
            0 => Fault::Drop,
            1 => Fault::Duplicate,
            2 => Fault::Delay,
            3 => Fault::Partition,
            _ => Fault::Truncate,
        }
    }
}

/// A running fault proxy. One upstream (the leader's replication
/// listener), one listening socket followers point at.
pub struct FaultProxy {
    pub local_addr: SocketAddr,
    injected: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl FaultProxy {
    /// Listen on an ephemeral port and relay every accepted connection to
    /// `upstream`, faulting leader→follower frames per the plan. The plan
    /// is shared across connections and reconnects (one budget for the
    /// proxy's lifetime).
    pub fn start(upstream: SocketAddr, plan: FaultPlan) -> std::io::Result<FaultProxy> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let injected = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let thread = {
            let injected = Arc::clone(&injected);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new().name("fault-proxy".into()).spawn(move || {
                let plan = Arc::new(Mutex::new(plan));
                let mut workers = Vec::new();
                loop {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((client, _)) => {
                            let plan = Arc::clone(&plan);
                            let injected = Arc::clone(&injected);
                            let stop = Arc::clone(&stop);
                            workers.push(std::thread::spawn(move || {
                                relay(client, upstream, &plan, &injected, &stop);
                            }));
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
                for w in workers {
                    let _ = w.join();
                }
            })?
        };
        Ok(FaultProxy { local_addr, injected, stop: Arc::clone(&stop), thread: Some(thread) })
    }

    /// Faults injected so far (proves the plan actually fired).
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Serve one proxied connection until either side closes or a
/// Truncate/Partition fault cuts it.
fn relay(
    client: TcpStream,
    upstream: SocketAddr,
    plan: &Mutex<FaultPlan>,
    injected: &AtomicU64,
    stop: &AtomicBool,
) {
    let Ok(server) = TcpStream::connect_timeout(&upstream, Duration::from_millis(500)) else {
        return;
    };
    client.set_nodelay(true).ok();
    server.set_nodelay(true).ok();

    // How many upstream (follower → leader) frames the pump must drop —
    // armed by a Partition fault on the downstream side, which is what
    // makes the split symmetric.
    let up_drop = Arc::new(AtomicU64::new(0));

    // Upstream direction (follower → leader): frame-aware pump so a
    // partition can swallow whole frames rather than shearing bytes.
    let up = {
        let (Ok(mut from), Ok(mut to)) = (client.try_clone(), server.try_clone()) else {
            return;
        };
        let up_drop = Arc::clone(&up_drop);
        std::thread::spawn(move || {
            loop {
                let Some(frame) = read_raw_frame(&mut from) else { break };
                if up_drop.load(Ordering::Relaxed) > 0 {
                    up_drop.fetch_sub(1, Ordering::Relaxed);
                    continue;
                }
                if to.write_all(&frame).is_err() {
                    break;
                }
            }
            to.shutdown(std::net::Shutdown::Write).ok();
        })
    };

    // Downstream direction (leader → follower): frame-by-frame faults.
    let mut from = server;
    let mut to = client;
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let Some(frame) = read_raw_frame(&mut from) else { break };
        let fault = plan.lock().unwrap_or_else(|e| e.into_inner()).decide();
        match fault {
            Fault::Forward => {
                if to.write_all(&frame).is_err() {
                    break;
                }
            }
            Fault::Drop => {
                injected.fetch_add(1, Ordering::Relaxed);
            }
            Fault::Duplicate => {
                injected.fetch_add(1, Ordering::Relaxed);
                if to.write_all(&frame).is_err() || to.write_all(&frame).is_err() {
                    break;
                }
            }
            Fault::Delay => {
                injected.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(20));
                if to.write_all(&frame).is_err() {
                    break;
                }
            }
            Fault::Truncate => {
                injected.fetch_add(1, Ordering::Relaxed);
                let cut = (frame.len() / 2).max(1);
                let _ = to.write_all(&frame[..cut]);
                break;
            }
            Fault::Partition => {
                injected.fetch_add(1, Ordering::Relaxed);
                // This frame is the first casualty; swallow the next few
                // in both directions, then cut. Each side just sees the
                // other go silent and then the link die.
                up_drop.store(PARTITION_FRAMES, Ordering::Relaxed);
                for _ in 1..PARTITION_FRAMES {
                    if read_raw_frame(&mut from).is_none() {
                        break;
                    }
                }
                break;
            }
        }
    }
    // Cut both sides so the follower reconnects promptly.
    to.shutdown(std::net::Shutdown::Both).ok();
    from.shutdown(std::net::Shutdown::Both).ok();
    let _ = up.join();
}

/// Read one whole frame (header + payload) as raw bytes, without
/// validating the CRC — the proxy relays damage, it does not repair it.
fn read_raw_frame(r: &mut TcpStream) -> Option<Vec<u8>> {
    use std::io::Read;
    let mut header = [0u8; HEADER_SIZE];
    let mut got = 0;
    while got < HEADER_SIZE {
        match r.read(&mut header[got..]) {
            Ok(0) | Err(_) => return None,
            Ok(n) => got += n,
        }
    }
    let len = u32::from_le_bytes(header[4..8].try_into().unwrap()) as usize;
    let mut frame = header.to_vec();
    frame.resize(HEADER_SIZE + len, 0);
    let mut got = 0;
    while got < len {
        match r.read(&mut frame[HEADER_SIZE + got..]) {
            Ok(0) | Err(_) => return None,
            Ok(n) => got += n,
        }
    }
    Some(frame)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    #[test]
    fn plan_is_deterministic_and_budgeted() {
        let decisions = |seed: u64| -> Vec<Fault> {
            let mut p = FaultPlan::new(seed, 50, 5);
            (0..100).map(|_| p.decide()).collect()
        };
        assert_eq!(decisions(7), decisions(7), "same seed, same schedule");
        let d = decisions(7);
        let faults = d.iter().filter(|f| **f != Fault::Forward).count();
        assert_eq!(faults, 5, "budget caps injections");
        assert!(
            d.iter().rev().take(50).all(|f| *f == Fault::Forward),
            "after the budget, everything forwards"
        );
    }

    #[test]
    fn partition_only_plans_draw_nothing_else() {
        let mut p = FaultPlan::partitions_only(3, 100, 3);
        let d: Vec<Fault> = (0..10).map(|_| p.decide()).collect();
        assert_eq!(&d[..3], &[Fault::Partition; 3]);
        assert!(d[3..].iter().all(|f| *f == Fault::Forward));
    }

    /// The proxy relays a framed stream faithfully when the plan injects
    /// nothing (0% fault chance).
    #[test]
    fn clean_plan_relays_frames_verbatim() {
        use crate::repl::frame::Frame;
        let upstream = TcpListener::bind("127.0.0.1:0").unwrap();
        let up_addr = upstream.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (s, _) = upstream.accept().unwrap();
            // Read the client's hello frame (upstream pump), then answer
            // with two frames.
            let mut r = BufReader::new(s.try_clone().unwrap());
            let hello = Frame::read_from(&mut r).unwrap();
            assert_eq!(hello, Some(Frame::Hello { last_seq: 9, need_snapshot: false }));
            let mut s = s;
            Frame::Ack { seq: 1 }.write_to(&mut s).unwrap();
            Frame::CaughtUp { seq: 1 }.write_to(&mut s).unwrap();
        });
        let proxy = FaultProxy::start(up_addr, FaultPlan::new(1, 0, 0)).unwrap();
        let mut c = TcpStream::connect(proxy.local_addr).unwrap();
        Frame::Hello { last_seq: 9, need_snapshot: false }.write_to(&mut c).unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        assert_eq!(Frame::read_from(&mut reader).unwrap(), Some(Frame::Ack { seq: 1 }));
        assert_eq!(
            Frame::read_from(&mut reader).unwrap(),
            Some(Frame::CaughtUp { seq: 1 })
        );
        assert_eq!(proxy.injected(), 0);
        server.join().unwrap();
        proxy.stop();
    }

    /// A partition fault swallows frames in both directions and cuts the
    /// link; a reconnect through the proxy then relays cleanly (budget
    /// spent).
    #[test]
    fn partition_is_symmetric_then_heals_on_reconnect() {
        use crate::repl::frame::Frame;
        let upstream = TcpListener::bind("127.0.0.1:0").unwrap();
        let up_addr = upstream.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            // First connection: partitioned. Send enough frames to burn
            // the partition, and count what arrives upstream.
            let (s, _) = upstream.accept().unwrap();
            let mut w = s.try_clone().unwrap();
            let mut r = BufReader::new(s);
            for seq in 1..=8 {
                if Frame::Ack { seq }.write_to(&mut w).is_err() {
                    break;
                }
            }
            let mut upstream_got = 0u64;
            while let Ok(Some(_)) = Frame::read_from(&mut r) {
                upstream_got += 1;
            }
            // Second connection: clean relay both ways.
            let (s, _) = upstream.accept().unwrap();
            let mut w = s.try_clone().unwrap();
            let mut r = BufReader::new(s);
            let hello = Frame::read_from(&mut r).unwrap();
            assert_eq!(hello, Some(Frame::Hello { last_seq: 0, need_snapshot: true }));
            Frame::Ack { seq: 99 }.write_to(&mut w).unwrap();
            upstream_got
        });

        // 100% fault chance, budget 1, partitions only: the very first
        // downstream frame arms the partition.
        let proxy = FaultProxy::start(up_addr, FaultPlan::partitions_only(11, 100, 1)).unwrap();
        let mut c = TcpStream::connect(proxy.local_addr).unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        // Everything the leader sent during the partition is gone: the
        // stream just ends (proxy cut it after swallowing the window).
        let mut downstream_got = 0u64;
        while let Ok(Some(_)) = Frame::read_from(&mut reader) {
            downstream_got += 1;
        }
        // Our frames written into the partition vanish too (the pump
        // drops them; the write itself may or may not error by then).
        for seq in 1..=PARTITION_FRAMES {
            let _ = Frame::Ack { seq }.write_to(&mut c);
        }
        drop(c);
        assert_eq!(proxy.injected(), 1);

        // Reconnect: the budget is spent, so the link is clean again.
        let mut c2 = TcpStream::connect(proxy.local_addr).unwrap();
        Frame::Hello { last_seq: 0, need_snapshot: true }.write_to(&mut c2).unwrap();
        let mut r2 = BufReader::new(c2.try_clone().unwrap());
        assert_eq!(Frame::read_from(&mut r2).unwrap(), Some(Frame::Ack { seq: 99 }));

        let upstream_got = server.join().unwrap();
        assert!(
            downstream_got < 8,
            "partition must swallow downstream frames (got {downstream_got})"
        );
        assert_eq!(upstream_got, 0, "acks written into the partition must vanish");
        proxy.stop();
    }
}
