//! Inner-product distance variant (Supplementary A of the paper).
//!
//! For maximum-inner-product search the decomposition is
//!
//! ```text
//! qᵀd = q_projᵀ d_proj + q_resᵀ d_res
//!     = qp·dp + ||q_res||·||d_res||·cos(q_res, d_res)
//! ```
//!
//! (projections are along the same center c, so their inner product is the
//! product of signed lengths). The same rank-r cosine estimator and
//! distribution matching apply unchanged; only the combination formula
//! differs. Angular/cosine similarity = inner product on normalized
//! vectors, which is how the angular datasets are served.

use crate::core::distance::dot;
use crate::finger::approx::QueryCenter;
use crate::finger::construct::FingerIndex;

/// Approximate inner product qᵀd for the edge at `slot` (Supplementary A).
/// NOTE: *larger* is better for IP search; callers negate when plugging
/// into min-heap machinery.
#[inline]
pub fn approx_ip(index: &FingerIndex, qc: &QueryCenter, slot: usize) -> f32 {
    let r = index.rank;
    let b = index.edge_block(slot);
    let (dp, dn, pn) = (b[0], b[1], b[2]);
    let pres = &b[crate::finger::construct::EDGE_SCALARS..];
    let denom = (qc.pq_res_norm * pn).max(1e-12);
    let t_hat = dot(&qc.pq_res[..r], pres) / denom;
    let m = &index.matching;
    let t = (t_hat - m.mu_hat) * (m.sigma / m.sigma_hat.max(1e-12)) + m.mu + m.eps;
    qc.q_proj * dp + qc.q_res_norm * dn * t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::{l2_sq, Metric};
    use crate::data::synth::tiny;
    use crate::finger::approx::QueryState;
    use crate::finger::construct::FingerParams;
    use crate::graph::hnsw::{Hnsw, HnswParams};

    /// Full-rank + identity matching: the IP estimate must be exact.
    #[test]
    fn full_rank_ip_is_exact() {
        let ds = tiny(601, 200, 8, Metric::L2);
        let h = Hnsw::build(&ds.data, HnswParams { m: 6, ef_construction: 40, ..Default::default() });
        let f = crate::finger::construct::FingerIndex::build(
            &ds.data,
            &h.base,
            FingerParams {
                rank: 8,
                distribution_matching: false,
                error_correction: false,
                ..Default::default()
            },
        );
        let q = ds.queries.row(0);
        let qs = QueryState::new(&f, q);
        for c in 0..ds.data.rows() as u32 {
            let dqc = l2_sq(q, ds.data.row(c as usize));
            let qc = QueryCenter::new(&f, &qs, c, dqc);
            for (j, &d) in h.base.neighbors(c).iter().enumerate() {
                let slot = h.base.edge_slot(c, j);
                let approx = approx_ip(&f, &qc, slot);
                let exact = dot(q, ds.data.row(d as usize));
                assert!(
                    (approx - exact).abs() < 2e-2 * (1.0 + exact.abs()),
                    "edge ({c},{d}): {approx} vs {exact}"
                );
            }
        }
    }

    /// L2 and IP estimates must be mutually consistent:
    /// ||q-d||² = ||q||² + ||d||² − 2 qᵀd.
    #[test]
    fn ip_and_l2_estimates_consistent() {
        let ds = tiny(602, 300, 24, Metric::L2);
        let h = Hnsw::build(&ds.data, HnswParams { m: 8, ef_construction: 40, ..Default::default() });
        let f = crate::finger::construct::FingerIndex::build(
            &ds.data,
            &h.base,
            FingerParams { rank: 8, ..Default::default() },
        );
        let q = ds.queries.row(1);
        let qs = QueryState::new(&f, q);
        let qsq = crate::core::distance::norm_sq(q);
        for c in (0..ds.data.rows() as u32).step_by(13) {
            let dqc = l2_sq(q, ds.data.row(c as usize));
            let qc = QueryCenter::new(&f, &qs, c, dqc);
            for (j, &d) in h.base.neighbors(c).iter().enumerate() {
                let slot = h.base.edge_slot(c, j);
                let ip = approx_ip(&f, &qc, slot);
                let l2 = crate::finger::approx::approx_dist_sq(&f, &qc, slot);
                let dsq = crate::core::distance::norm_sq(ds.data.row(d as usize));
                let reconstructed = qsq + dsq - 2.0 * ip;
                assert!(
                    (l2 - reconstructed).abs() < 1e-2 * (1.0 + l2.abs()),
                    "edge ({c},{d}): l2 {l2} vs reconstructed {reconstructed}"
                );
            }
        }
    }
}
