//! Algorithm 4: approximate greedy graph search.
//!
//! Identical control flow to Algorithm 1 except neighbor screening: once
//! the top-results queue is full, each neighbor is first scored with the
//! FINGER approximate distance; only if the approximation beats the upper
//! bound is the exact m-dimensional distance computed (Supplementary G —
//! the candidate queue only ever holds *exact* distances, so termination
//! logic is unchanged and the search cannot stop early due to
//! approximation error).

use crate::core::distance::l2_sq;
use crate::core::matrix::Matrix;
use crate::finger::approx::{approx_dist_sq, QueryCenter, QueryState};
use crate::finger::construct::FingerIndex;
use crate::graph::adjacency::FlatAdj;
use crate::graph::search::{MinNeighbor, Neighbor};
use crate::index::context::{SearchContext, SearchParams};
use crate::index::mutable::LiveIds;

/// FINGER-screened beam search over one adjacency layer.
pub fn finger_beam_search(
    data: &Matrix,
    adj: &FlatAdj,
    index: &FingerIndex,
    entry: u32,
    q: &[f32],
    ef: usize,
    ctx: &mut SearchContext,
) -> Vec<Neighbor> {
    ctx.begin(data.rows());
    ctx.visited.insert(entry);
    let qs = QueryState::new(index, q);
    let d0 = l2_sq(q, data.row(entry as usize));
    if ctx.stats_enabled {
        ctx.stats.dist_calls += 1;
    }

    ctx.cands.push(MinNeighbor(Neighbor { dist: d0, id: entry }));
    ctx.top.push(Neighbor { dist: d0, id: entry });

    while let Some(MinNeighbor(cur)) = ctx.cands.pop() {
        let ub = ctx.top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY);
        if cur.dist > ub && ctx.top.len() >= ef {
            break;
        }
        if ctx.stats_enabled {
            ctx.stats.hops += 1;
        }
        // Lazily built: only pay the query-center setup if we actually
        // screen at least one neighbor approximately.
        let mut qc: Option<QueryCenter> = None;
        for (j, &nb) in adj.neighbors(cur.id).iter().enumerate() {
            if !ctx.visited.insert(nb) {
                continue;
            }
            let ub_now = ctx.top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY);
            let full = ctx.top.len() >= ef;
            if full {
                // Screen with Algorithm 3 before paying the m-dim distance.
                let qc = qc.get_or_insert_with(|| QueryCenter::new(index, &qs, cur.id, cur.dist));
                let slot = adj.edge_slot(cur.id, j);
                let approx = approx_dist_sq(index, qc, slot);
                if ctx.stats_enabled {
                    ctx.stats.approx_calls += 1;
                }
                if approx > ub_now {
                    continue; // screened out: skip the exact computation
                }
            }
            let d = l2_sq(q, data.row(nb as usize));
            if ctx.stats_enabled {
                ctx.stats.dist_calls += 1;
            }
            if !full || d < ub_now {
                ctx.cands.push(MinNeighbor(Neighbor { dist: d, id: nb }));
                ctx.top.push(Neighbor { dist: d, id: nb });
                if ctx.top.len() > ef {
                    ctx.top.pop();
                }
            }
        }
    }

    ctx.drain_top()
}

/// Tombstone-aware FINGER-screened beam search: the online-update variant
/// of [`finger_beam_search`]. Deleted nodes still route (they stay in the
/// candidate queue) but never reach the top-results queue, so the upper
/// bound screening compares against comes from live results only and a
/// deleted row can never be emitted. Returns row ids.
#[allow(clippy::too_many_arguments)]
pub fn finger_beam_search_live(
    data: &Matrix,
    adj: &FlatAdj,
    index: &FingerIndex,
    entry: u32,
    q: &[f32],
    ef: usize,
    live: &LiveIds,
    ctx: &mut SearchContext,
) -> Vec<Neighbor> {
    ctx.begin(data.rows());
    ctx.visited.insert(entry);
    let qs = QueryState::new(index, q);
    let d0 = l2_sq(q, data.row(entry as usize));
    if ctx.stats_enabled {
        ctx.stats.dist_calls += 1;
    }

    ctx.cands.push(MinNeighbor(Neighbor { dist: d0, id: entry }));
    if !live.is_dead_row(entry as usize) {
        ctx.top.push(Neighbor { dist: d0, id: entry });
    }

    while let Some(MinNeighbor(cur)) = ctx.cands.pop() {
        let ub = ctx.top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY);
        if cur.dist > ub && ctx.top.len() >= ef {
            break;
        }
        if ctx.stats_enabled {
            ctx.stats.hops += 1;
        }
        let mut qc: Option<QueryCenter> = None;
        for (j, &nb) in adj.neighbors(cur.id).iter().enumerate() {
            if !ctx.visited.insert(nb) {
                continue;
            }
            let ub_now = ctx.top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY);
            let full = ctx.top.len() >= ef;
            if full {
                let qc = qc.get_or_insert_with(|| QueryCenter::new(index, &qs, cur.id, cur.dist));
                let slot = adj.edge_slot(cur.id, j);
                let approx = approx_dist_sq(index, qc, slot);
                if ctx.stats_enabled {
                    ctx.stats.approx_calls += 1;
                }
                if approx > ub_now {
                    continue;
                }
            }
            let d = l2_sq(q, data.row(nb as usize));
            if ctx.stats_enabled {
                ctx.stats.dist_calls += 1;
            }
            if !full || d < ub_now {
                ctx.cands.push(MinNeighbor(Neighbor { dist: d, id: nb }));
                if !live.is_dead_row(nb as usize) {
                    ctx.top.push(Neighbor { dist: d, id: nb });
                    if ctx.top.len() > ef {
                        ctx.top.pop();
                    }
                }
            }
        }
    }

    ctx.drain_top()
}

/// FINGER-screened HNSW search over *borrowed* graph + index (lets callers
/// share one graph across many FINGER/RPLSH index variants — the Figure 6
/// ablation sweeps dozens of (rank, scheme) combinations on one graph).
///
/// `params.patience` is ignored: screening already cheapens the work that
/// early termination would skip, and mixing both would change Algorithm 4.
pub fn search_hnsw_with_index(
    hnsw: &crate::graph::hnsw::Hnsw,
    index: &FingerIndex,
    data: &Matrix,
    q: &[f32],
    params: &SearchParams,
    ctx: &mut SearchContext,
) -> Vec<Neighbor> {
    let mut cur = hnsw.entry;
    for l in (1..=hnsw.max_level).rev() {
        cur = crate::graph::search::greedy_descent(data, &hnsw.upper[l - 1], cur, q, ctx).id;
    }
    let mut res = finger_beam_search(data, &hnsw.base, index, cur, q, params.beam_width(), ctx);
    res.truncate(params.k);
    res
}

/// HNSW + FINGER: exact greedy descent on the upper layers (they are tiny),
/// FINGER-screened beam search on the base layer — matching the paper's
/// HNSW-FINGER system.
pub struct FingerHnsw {
    pub hnsw: crate::graph::hnsw::Hnsw,
    pub index: FingerIndex,
}

impl FingerHnsw {
    pub fn build(
        data: &Matrix,
        hnsw_params: crate::graph::hnsw::HnswParams,
        finger_params: crate::finger::construct::FingerParams,
    ) -> FingerHnsw {
        let hnsw = crate::graph::hnsw::Hnsw::build(data, hnsw_params);
        let index = FingerIndex::build(data, &hnsw.base, finger_params);
        FingerHnsw { hnsw, index }
    }

    pub fn search(
        &self,
        data: &Matrix,
        q: &[f32],
        params: &SearchParams,
        ctx: &mut SearchContext,
    ) -> Vec<Neighbor> {
        search_hnsw_with_index(&self.hnsw, &self.index, data, q, params, ctx)
    }

    /// Tombstone-aware variant of [`FingerHnsw::search`]: same routing,
    /// but the base-layer beam never emits deleted rows. Returns row ids;
    /// callers remap to external ids.
    pub fn search_live(
        &self,
        data: &Matrix,
        q: &[f32],
        params: &SearchParams,
        live: &LiveIds,
        ctx: &mut SearchContext,
    ) -> Vec<Neighbor> {
        let mut cur = self.hnsw.entry;
        for l in (1..=self.hnsw.max_level).rev() {
            cur = crate::graph::search::greedy_descent(data, &self.hnsw.upper[l - 1], cur, q, ctx)
                .id;
        }
        let mut res = finger_beam_search_live(
            data,
            &self.hnsw.base,
            &self.index,
            cur,
            q,
            params.beam_width(),
            live,
            ctx,
        );
        res.truncate(params.k);
        res
    }

    /// Total index bytes: graph adjacency + FINGER tables.
    pub fn nbytes(&self) -> usize {
        self.hnsw.nbytes() + self.index.nbytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::Metric;
    use crate::data::groundtruth::exact_knn;
    use crate::data::synth::tiny;
    use crate::finger::construct::FingerParams;
    use crate::graph::hnsw::HnswParams;

    fn avg_recall(
        fh: &FingerHnsw,
        ds: &crate::data::synth::Dataset,
        gt: &[Vec<u32>],
        ef: usize,
        ctx: &mut SearchContext,
    ) -> f64 {
        let params = SearchParams::new(10).with_ef(ef);
        let mut total = 0.0;
        for qi in 0..ds.queries.rows() {
            let res = fh.search(&ds.data, ds.queries.row(qi), &params, ctx);
            let hits = res.iter().filter(|n| gt[qi].contains(&n.id)).count();
            total += hits as f64 / 10.0;
        }
        total / ds.queries.rows() as f64
    }

    #[test]
    fn finger_maintains_high_recall() {
        let ds = tiny(71, 800, 32, Metric::L2);
        let fh = FingerHnsw::build(
            &ds.data,
            HnswParams { m: 12, ef_construction: 80, ..Default::default() },
            FingerParams { rank: 16, ..Default::default() },
        );
        let gt = exact_knn(&ds.data, &ds.queries, 10);
        let mut ctx = SearchContext::new();
        let r = avg_recall(&fh, &ds, &gt, 80, &mut ctx);
        assert!(r > 0.85, "recall@10 = {r}");
    }

    #[test]
    fn finger_reduces_full_distance_calls() {
        let ds = tiny(72, 800, 48, Metric::L2);
        let hnsw_p = HnswParams { m: 12, ef_construction: 80, ..Default::default() };
        let fh = FingerHnsw::build(&ds.data, hnsw_p.clone(), FingerParams { rank: 8, ..Default::default() });
        let gt = exact_knn(&ds.data, &ds.queries, 10);

        let mut ctx = SearchContext::new().with_stats();
        let r_f = avg_recall(&fh, &ds, &gt, 60, &mut ctx);
        let finger_stats = ctx.take_stats();

        // Baseline: plain HNSW search on the same graph.
        let params = SearchParams::new(10).with_ef(60);
        for qi in 0..ds.queries.rows() {
            fh.hnsw.search(&ds.data, ds.queries.row(qi), &params, &mut ctx);
        }
        let plain_stats = ctx.take_stats();

        assert!(
            finger_stats.dist_calls < plain_stats.dist_calls,
            "finger {} vs plain {} full-distance calls",
            finger_stats.dist_calls,
            plain_stats.dist_calls
        );
        assert!(finger_stats.approx_calls > 0);
        assert!(r_f > 0.8, "recall with screening = {r_f}");
    }

    #[test]
    fn results_sorted_and_unique() {
        let ds = tiny(73, 300, 16, Metric::L2);
        let fh = FingerHnsw::build(
            &ds.data,
            HnswParams { m: 8, ef_construction: 40, ..Default::default() },
            FingerParams { rank: 8, ..Default::default() },
        );
        let mut ctx = SearchContext::new();
        let res = fh.search(&ds.data, ds.queries.row(0), &SearchParams::new(10).with_ef(50), &mut ctx);
        for w in res.windows(2) {
            assert!(w[0].dist <= w[1].dist);
        }
        let mut ids: Vec<u32> = res.iter().map(|n| n.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), res.len());
    }

    #[test]
    fn angular_dataset_works() {
        let ds = tiny(74, 500, 24, Metric::Angular);
        let fh = FingerHnsw::build(
            &ds.data,
            HnswParams { m: 8, ef_construction: 60, ..Default::default() },
            FingerParams { rank: 8, ..Default::default() },
        );
        let gt = exact_knn(&ds.data, &ds.queries, 10);
        let mut ctx = SearchContext::new();
        let r = avg_recall(&fh, &ds, &gt, 60, &mut ctx);
        assert!(r > 0.8, "angular recall@10 = {r}");
    }
}
