//! Algorithm 4: approximate greedy graph search.
//!
//! Identical control flow to Algorithm 1 except neighbor screening: once
//! the top-results queue is full, each neighbor is first scored with the
//! FINGER approximate distance; only if the approximation beats the upper
//! bound is the exact m-dimensional distance computed (Supplementary G —
//! the candidate queue only ever holds *exact* distances, so termination
//! logic is unchanged and the search cannot stop early due to
//! approximation error).
//!
//! Like the plain beam search there is exactly one copy of the hot loop,
//! [`finger_beam_search_filtered`], generic over a [`LiveFilter`] and
//! switchable between scalar and batched scoring. Batching here is
//! restricted to where it cannot change decisions: while the top queue is
//! still filling, every neighbor needs an exact distance anyway, so those
//! are computed 4 rows per kernel pass; once the queue is full, screening
//! depends on the *evolving* upper bound, so the screen→maybe-exact
//! sequence stays per-neighbor (that stream is cheap — one contiguous
//! SoA edge-block read per neighbor). Both modes therefore make identical
//! admission and screening decisions and return bitwise-identical result
//! streams with identical stats.

use crate::core::distance::{l2_sq, l2_sq_batch4, l2_sq_scalar, prefetch_l1};
use crate::core::matrix::Matrix;
use crate::core::store::VectorStore;
use crate::finger::approx::{approx_dist_sq, QueryCenter, QueryState};
use crate::finger::construct::FingerIndex;
use crate::graph::adjacency::FlatAdj;
use crate::graph::search::{AllLive, ApproxScorer, LiveFilter, MinNeighbor, Neighbor};
use crate::index::context::{SearchContext, SearchParams};
use crate::index::mutable::LiveIds;

/// Process one gathered neighbor exactly the way the scalar Algorithm 4
/// loop does: screen if the top queue is full, then (maybe) take the
/// exact distance — `pre` supplies it when the fill-phase batch already
/// computed it, `exact` is the kernel to use otherwise (dispatched, or
/// the portable scalar fallback in unbatched mode) — and admit against
/// the cached upper bound. All counting goes through
/// `SearchStats::{record, record_approx}` so `per_hop` and `wasted` (the
/// Figure 2 data) are populated on the FINGER path too.
#[allow(clippy::too_many_arguments)]
#[inline]
fn admit_screened<F: LiveFilter + ?Sized>(
    store: &VectorStore,
    index: &FingerIndex,
    qs: &QueryState,
    qp: &[f32],
    cur: Neighbor,
    nb: u32,
    slot: usize,
    pre: Option<f32>,
    exact: fn(&[f32], &[f32]) -> f32,
    ef: usize,
    hop: usize,
    ub: &mut f32,
    qc: &mut Option<QueryCenter>,
    filter: &F,
    ctx: &mut SearchContext,
) {
    let full = ctx.top.len() >= ef;
    if full {
        // Screen with Algorithm 3 before paying the m-dim distance.
        let qc = qc.get_or_insert_with(|| QueryCenter::new(index, qs, cur.id, cur.dist));
        let approx = approx_dist_sq(index, qc, slot);
        if ctx.stats_enabled {
            ctx.stats.record_approx();
        }
        if approx > *ub {
            return; // screened out: the exact computation is skipped
        }
    }
    let d = pre.unwrap_or_else(|| exact(qp, store.row(nb as usize)));
    if ctx.stats_enabled {
        ctx.stats.record(hop, full && d > *ub);
    }
    if !full || d < *ub {
        ctx.cands.push(MinNeighbor(Neighbor { dist: d, id: nb }));
        if filter.emits(nb) {
            ctx.top.push(Neighbor { dist: d, id: nb });
            if ctx.top.len() > ef {
                ctx.top.pop();
            }
            *ub = ctx.top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY);
        }
    }
}

/// FINGER-screened beam search over one adjacency layer — the single hot
/// loop behind [`finger_beam_search`] and [`finger_beam_search_live`].
#[allow(clippy::too_many_arguments)]
pub fn finger_beam_search_filtered<F: LiveFilter + ?Sized>(
    store: &VectorStore,
    adj: &FlatAdj,
    index: &FingerIndex,
    entry: u32,
    q: &[f32],
    ef: usize,
    filter: &F,
    batched: bool,
    ctx: &mut SearchContext,
) -> Vec<Neighbor> {
    ctx.begin(store.rows());
    let mut qp = std::mem::take(&mut ctx.qbuf);
    let mut block = std::mem::take(&mut ctx.block);
    let mut slots = std::mem::take(&mut ctx.slots);
    store.pad_query(q, &mut qp);

    // Unbatched mode doubles as the full fallback: exact distances go
    // through the portable scalar kernels, bypassing the SIMD dispatch
    // (bitwise-identical either way).
    let exact: fn(&[f32], &[f32]) -> f32 = if batched { l2_sq } else { l2_sq_scalar };

    let qs = QueryState::new(index, q);
    ctx.visited.insert(entry);
    let d0 = exact(&qp, store.row(entry as usize));
    if ctx.stats_enabled {
        ctx.stats.dist_calls += 1;
    }
    ctx.cands.push(MinNeighbor(Neighbor { dist: d0, id: entry }));
    if filter.emits(entry) {
        ctx.top.push(Neighbor { dist: d0, id: entry });
    }

    let mut hop = 0usize;
    while let Some(MinNeighbor(cur)) = ctx.cands.pop() {
        let mut ub = ctx.top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY);
        if cur.dist > ub && ctx.top.len() >= ef {
            break;
        }
        if ctx.stats_enabled {
            ctx.stats.hops += 1;
        }
        // Lazily built: only pay the query-center setup if we actually
        // screen at least one neighbor approximately.
        let mut qc: Option<QueryCenter> = None;

        // Gather the unvisited neighbors (and their stable edge slots)
        // first; a node's slots are consecutive, so the screening phase
        // below walks one contiguous SoA stream.
        block.clear();
        slots.clear();
        for (j, &nb) in adj.neighbors(cur.id).iter().enumerate() {
            if ctx.visited.insert(nb) {
                block.push(nb);
                slots.push(adj.edge_slot(cur.id, j));
            }
        }

        let mut i = 0;
        while i < block.len() {
            if batched && ctx.top.len() < ef && i + 4 <= block.len() {
                // Fill phase: everything gets an exact distance anyway, so
                // score 4 rows per kernel pass (prefetching the next
                // sub-block's rows toward L1 first). If the queue fills
                // inside this sub-block, `admit_screened` switches to
                // screening for the rest — the precomputed distance is
                // only used when the scalar path would have computed it,
                // so decisions and stats stay identical.
                if i + 8 <= block.len() {
                    for t in i + 4..i + 8 {
                        prefetch_l1(store.row(block[t] as usize).as_ptr());
                    }
                }
                let d4 = l2_sq_batch4(
                    &qp,
                    store.row(block[i] as usize),
                    store.row(block[i + 1] as usize),
                    store.row(block[i + 2] as usize),
                    store.row(block[i + 3] as usize),
                );
                for (t, &d) in d4.iter().enumerate() {
                    admit_screened(
                        store,
                        index,
                        &qs,
                        &qp,
                        cur,
                        block[i + t],
                        slots[i + t],
                        Some(d),
                        exact,
                        ef,
                        hop,
                        &mut ub,
                        &mut qc,
                        filter,
                        ctx,
                    );
                }
                i += 4;
            } else {
                admit_screened(
                    store,
                    index,
                    &qs,
                    &qp,
                    cur,
                    block[i],
                    slots[i],
                    None,
                    exact,
                    ef,
                    hop,
                    &mut ub,
                    &mut qc,
                    filter,
                    ctx,
                );
                i += 1;
            }
        }
        hop += 1;
    }

    ctx.qbuf = qp;
    ctx.block = block;
    ctx.slots = slots;
    ctx.drain_top()
}

/// Quantized FINGER beam search: the FINGER screen (Algorithm 3, built
/// from the f32 query exactly as in the exact core) composes with a
/// quantized admission distance — a neighbor that survives the screen is
/// scored by the [`ApproxScorer`] (SQ8 / PQ codes) instead of the f32
/// kernel, so the hot loop never touches full-precision rows at all.
/// Both estimates target the same squared-L2 scale, so the screen's
/// upper-bound comparison stays meaningful. All in-loop scoring counts
/// as `approx_calls`; callers restore exact ordering with
/// [`crate::graph::search::rerank_exact`] over the full returned pool.
#[allow(clippy::too_many_arguments)]
pub fn finger_beam_search_approx_filtered<F: LiveFilter + ?Sized, S: ApproxScorer>(
    n_rows: usize,
    adj: &FlatAdj,
    index: &FingerIndex,
    entry: u32,
    q: &[f32],
    ef: usize,
    filter: &F,
    scorer: &mut S,
    ctx: &mut SearchContext,
) -> Vec<Neighbor> {
    ctx.begin(n_rows);
    let mut block = std::mem::take(&mut ctx.block);
    let mut slots = std::mem::take(&mut ctx.slots);

    let qs = QueryState::new(index, q);
    ctx.visited.insert(entry);
    let d0 = scorer.dist(entry as usize);
    if ctx.stats_enabled {
        ctx.stats.record_approx();
    }
    ctx.cands.push(MinNeighbor(Neighbor { dist: d0, id: entry }));
    if filter.emits(entry) {
        ctx.top.push(Neighbor { dist: d0, id: entry });
    }

    while let Some(MinNeighbor(cur)) = ctx.cands.pop() {
        let mut ub = ctx.top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY);
        if cur.dist > ub && ctx.top.len() >= ef {
            break;
        }
        if ctx.stats_enabled {
            ctx.stats.hops += 1;
        }
        let mut qc: Option<QueryCenter> = None;

        block.clear();
        slots.clear();
        for (j, &nb) in adj.neighbors(cur.id).iter().enumerate() {
            if ctx.visited.insert(nb) {
                block.push(nb);
                slots.push(adj.edge_slot(cur.id, j));
            }
        }

        for (i, &nb) in block.iter().enumerate() {
            let full = ctx.top.len() >= ef;
            if full {
                let qc = qc.get_or_insert_with(|| QueryCenter::new(index, &qs, cur.id, cur.dist));
                let approx = approx_dist_sq(index, qc, slots[i]);
                if ctx.stats_enabled {
                    ctx.stats.record_approx();
                }
                if approx > ub {
                    continue; // screened out before any code-row read
                }
            }
            let d = scorer.dist(nb as usize);
            if ctx.stats_enabled {
                ctx.stats.record_approx();
            }
            if !full || d < ub {
                ctx.cands.push(MinNeighbor(Neighbor { dist: d, id: nb }));
                if filter.emits(nb) {
                    ctx.top.push(Neighbor { dist: d, id: nb });
                    if ctx.top.len() > ef {
                        ctx.top.pop();
                    }
                    ub = ctx.top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY);
                }
            }
        }
    }

    ctx.block = block;
    ctx.slots = slots;
    ctx.drain_top()
}

/// FINGER-screened beam search over one adjacency layer.
pub fn finger_beam_search(
    store: &VectorStore,
    adj: &FlatAdj,
    index: &FingerIndex,
    entry: u32,
    q: &[f32],
    ef: usize,
    ctx: &mut SearchContext,
) -> Vec<Neighbor> {
    finger_beam_search_filtered(store, adj, index, entry, q, ef, &AllLive, true, ctx)
}

/// Tombstone-aware FINGER-screened beam search: the online-update variant
/// of [`finger_beam_search`]. Deleted nodes still route (they stay in the
/// candidate queue) but never reach the top-results queue, so the upper
/// bound screening compares against comes from live results only and a
/// deleted row can never be emitted. Returns row ids.
#[allow(clippy::too_many_arguments)]
pub fn finger_beam_search_live(
    store: &VectorStore,
    adj: &FlatAdj,
    index: &FingerIndex,
    entry: u32,
    q: &[f32],
    ef: usize,
    live: &LiveIds,
    ctx: &mut SearchContext,
) -> Vec<Neighbor> {
    finger_beam_search_filtered(store, adj, index, entry, q, ef, live, true, ctx)
}

/// FINGER-screened HNSW search over *borrowed* graph + index (lets callers
/// share one graph across many FINGER/RPLSH index variants — the Figure 6
/// ablation sweeps dozens of (rank, scheme) combinations on one graph).
///
/// `params.patience` is ignored: screening already cheapens the work that
/// early termination would skip, and mixing both would change Algorithm 4.
pub fn search_hnsw_with_index(
    hnsw: &crate::graph::hnsw::Hnsw,
    index: &FingerIndex,
    store: &VectorStore,
    q: &[f32],
    params: &SearchParams,
    ctx: &mut SearchContext,
) -> Vec<Neighbor> {
    let mut cur = hnsw.entry;
    for l in (1..=hnsw.max_level).rev() {
        cur = crate::graph::search::greedy_descent(store, &hnsw.upper[l - 1], cur, q, ctx).id;
    }
    let mut res = finger_beam_search_filtered(
        store,
        &hnsw.base,
        index,
        cur,
        q,
        params.beam_width(),
        &AllLive,
        !params.scalar_kernels,
        ctx,
    );
    res.truncate(params.k);
    res
}

/// HNSW + FINGER: exact greedy descent on the upper layers (they are tiny),
/// FINGER-screened beam search on the base layer — matching the paper's
/// HNSW-FINGER system.
pub struct FingerHnsw {
    pub hnsw: crate::graph::hnsw::Hnsw,
    pub index: FingerIndex,
}

impl FingerHnsw {
    pub fn build(
        data: &Matrix,
        hnsw_params: crate::graph::hnsw::HnswParams,
        finger_params: crate::finger::construct::FingerParams,
    ) -> FingerHnsw {
        let store = VectorStore::from_matrix(data);
        FingerHnsw::build_with_store(data, &store, hnsw_params, finger_params)
    }

    /// Build against an existing padded store (`store` must mirror `data`
    /// row-for-row; `data` is still needed for the FINGER residual SVD).
    pub fn build_with_store(
        data: &Matrix,
        store: &VectorStore,
        hnsw_params: crate::graph::hnsw::HnswParams,
        finger_params: crate::finger::construct::FingerParams,
    ) -> FingerHnsw {
        let hnsw = crate::graph::hnsw::Hnsw::build_with_store(store, hnsw_params);
        let index = FingerIndex::build(data, &hnsw.base, finger_params);
        FingerHnsw { hnsw, index }
    }

    pub fn search(
        &self,
        store: &VectorStore,
        q: &[f32],
        params: &SearchParams,
        ctx: &mut SearchContext,
    ) -> Vec<Neighbor> {
        search_hnsw_with_index(&self.hnsw, &self.index, store, q, params, ctx)
    }

    /// Tombstone-aware variant of [`FingerHnsw::search`]: same routing,
    /// but the base-layer beam never emits deleted rows. Returns row ids;
    /// callers remap to external ids.
    pub fn search_live(
        &self,
        store: &VectorStore,
        q: &[f32],
        params: &SearchParams,
        live: &LiveIds,
        ctx: &mut SearchContext,
    ) -> Vec<Neighbor> {
        let mut cur = self.hnsw.entry;
        for l in (1..=self.hnsw.max_level).rev() {
            cur = crate::graph::search::greedy_descent(store, &self.hnsw.upper[l - 1], cur, q, ctx)
                .id;
        }
        let mut res = finger_beam_search_filtered(
            store,
            &self.hnsw.base,
            &self.index,
            cur,
            q,
            params.beam_width(),
            live,
            !params.scalar_kernels,
            ctx,
        );
        res.truncate(params.k);
        res
    }

    /// Total index bytes: graph adjacency + FINGER tables.
    pub fn nbytes(&self) -> usize {
        self.hnsw.nbytes() + self.index.nbytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::Metric;
    use crate::data::groundtruth::exact_knn;
    use crate::data::synth::tiny;
    use crate::finger::construct::FingerParams;
    use crate::graph::hnsw::HnswParams;

    fn avg_recall(
        fh: &FingerHnsw,
        store: &VectorStore,
        ds: &crate::data::synth::Dataset,
        gt: &[Vec<u32>],
        ef: usize,
        ctx: &mut SearchContext,
    ) -> f64 {
        let params = SearchParams::new(10).with_ef(ef);
        let mut total = 0.0;
        for qi in 0..ds.queries.rows() {
            let res = fh.search(store, ds.queries.row(qi), &params, ctx);
            let hits = res.iter().filter(|n| gt[qi].contains(&n.id)).count();
            total += hits as f64 / 10.0;
        }
        total / ds.queries.rows() as f64
    }

    #[test]
    fn finger_maintains_high_recall() {
        let ds = tiny(71, 800, 32, Metric::L2);
        let store = VectorStore::from_matrix(&ds.data);
        let fh = FingerHnsw::build_with_store(
            &ds.data,
            &store,
            HnswParams { m: 12, ef_construction: 80, ..Default::default() },
            FingerParams { rank: 16, ..Default::default() },
        );
        let gt = exact_knn(&ds.data, &ds.queries, 10);
        let mut ctx = SearchContext::new();
        let r = avg_recall(&fh, &store, &ds, &gt, 80, &mut ctx);
        assert!(r > 0.85, "recall@10 = {r}");
    }

    #[test]
    fn finger_reduces_full_distance_calls() {
        let ds = tiny(72, 800, 48, Metric::L2);
        let store = VectorStore::from_matrix(&ds.data);
        let hnsw_p = HnswParams { m: 12, ef_construction: 80, ..Default::default() };
        let fh = FingerHnsw::build_with_store(
            &ds.data,
            &store,
            hnsw_p.clone(),
            FingerParams { rank: 8, ..Default::default() },
        );
        let gt = exact_knn(&ds.data, &ds.queries, 10);

        let mut ctx = SearchContext::new().with_stats();
        let r_f = avg_recall(&fh, &store, &ds, &gt, 60, &mut ctx);
        let finger_stats = ctx.take_stats();

        // Baseline: plain HNSW search on the same graph.
        let params = SearchParams::new(10).with_ef(60);
        for qi in 0..ds.queries.rows() {
            fh.hnsw.search(&store, ds.queries.row(qi), &params, &mut ctx);
        }
        let plain_stats = ctx.take_stats();

        assert!(
            finger_stats.dist_calls < plain_stats.dist_calls,
            "finger {} vs plain {} full-distance calls",
            finger_stats.dist_calls,
            plain_stats.dist_calls
        );
        assert!(finger_stats.approx_calls > 0);
        // Satellite fix: the FINGER path now buckets its exact-distance
        // work per hop, so Figure 2 data exists for screened searches too
        // (only entry/descent distances live outside the buckets).
        assert!(!finger_stats.per_hop.is_empty(), "per_hop not populated");
        let bucket_total: u64 = finger_stats.per_hop.iter().map(|x| x.0).sum();
        assert!(bucket_total > 0, "per_hop counted nothing");
        assert!(bucket_total <= finger_stats.dist_calls);
        assert!(
            finger_stats.wasted <= finger_stats.dist_calls,
            "wasted accounting broken"
        );
        assert!(r_f > 0.8, "recall with screening = {r_f}");
    }

    /// Batched and scalar FINGER searches must return bitwise-identical
    /// streams with identical stats — including with tombstones.
    #[test]
    fn batched_and_scalar_finger_streams_identical() {
        let ds = tiny(75, 600, 28, Metric::L2); // dim not a lane multiple
        let store = VectorStore::from_matrix(&ds.data);
        let fh = FingerHnsw::build_with_store(
            &ds.data,
            &store,
            HnswParams { m: 10, ef_construction: 60, ..Default::default() },
            FingerParams { rank: 8, ..Default::default() },
        );
        let mut live = LiveIds::fresh(600);
        for dead in [3usize, 77, 400, 401, 402] {
            live.kill_row(dead);
        }
        let mut ctx = SearchContext::new().with_stats();
        for qi in 0..ds.queries.rows().min(10) {
            let q = ds.queries.row(qi);
            for ef in [10usize, 40, 90] {
                let b = finger_beam_search_filtered(
                    &store, &fh.hnsw.base, &fh.index, fh.hnsw.entry, q, ef, &AllLive, true,
                    &mut ctx,
                );
                let sb = ctx.take_stats();
                let s = finger_beam_search_filtered(
                    &store, &fh.hnsw.base, &fh.index, fh.hnsw.entry, q, ef, &AllLive, false,
                    &mut ctx,
                );
                let ss = ctx.take_stats();
                assert_eq!(b, s, "q{qi} ef={ef}");
                assert_eq!(sb.dist_calls, ss.dist_calls, "q{qi} ef={ef}");
                assert_eq!(sb.approx_calls, ss.approx_calls, "q{qi} ef={ef}");
                assert_eq!(sb.wasted, ss.wasted, "q{qi} ef={ef}");
                assert_eq!(sb.per_hop, ss.per_hop, "q{qi} ef={ef}");
                let bl = finger_beam_search_filtered(
                    &store, &fh.hnsw.base, &fh.index, fh.hnsw.entry, q, ef, &live, true,
                    &mut ctx,
                );
                let sl = finger_beam_search_filtered(
                    &store, &fh.hnsw.base, &fh.index, fh.hnsw.entry, q, ef, &live, false,
                    &mut ctx,
                );
                assert_eq!(bl, sl, "live q{qi} ef={ef}");
            }
        }
    }

    #[test]
    fn results_sorted_and_unique() {
        let ds = tiny(73, 300, 16, Metric::L2);
        let store = VectorStore::from_matrix(&ds.data);
        let fh = FingerHnsw::build_with_store(
            &ds.data,
            &store,
            HnswParams { m: 8, ef_construction: 40, ..Default::default() },
            FingerParams { rank: 8, ..Default::default() },
        );
        let mut ctx = SearchContext::new();
        let res = fh.search(&store, ds.queries.row(0), &SearchParams::new(10).with_ef(50), &mut ctx);
        for w in res.windows(2) {
            assert!(w[0].dist <= w[1].dist);
        }
        let mut ids: Vec<u32> = res.iter().map(|n| n.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), res.len());
    }

    #[test]
    fn angular_dataset_works() {
        let ds = tiny(74, 500, 24, Metric::Angular);
        let store = VectorStore::from_matrix(&ds.data);
        let fh = FingerHnsw::build_with_store(
            &ds.data,
            &store,
            HnswParams { m: 8, ef_construction: 60, ..Default::default() },
            FingerParams { rank: 8, ..Default::default() },
        );
        let gt = exact_knn(&ds.data, &ds.queries, 10);
        let mut ctx = SearchContext::new();
        let r = avg_recall(&fh, &store, &ds, &gt, 60, &mut ctx);
        assert!(r > 0.8, "angular recall@10 = {r}");
    }
}
