//! RPLSH baseline (Charikar, STOC 2002): random-projection angle
//! estimation, the paper's Figure 6 ablation comparator.
//!
//! Two variants:
//! * `build_rplsh_index` — same index structure as FINGER but with a
//!   *random Gaussian* projection instead of the SVD basis (rows
//!   orthonormalized so cosines are preserved in expectation). Plugs
//!   straight into Algorithm 4, which is how the paper runs the
//!   "RPLSH (+DM)" ablation series.
//! * `SignLsh` — the classic sign-bit / Hamming estimator
//!   (angle ≈ hamming · π / r), kept as a standalone utility to document
//!   why the continuous variant is the right comparator (the sign
//!   estimator quantizes too coarsely at small r).

use crate::core::distance::dot;
use crate::core::matrix::Matrix;
use crate::core::rng::Pcg32;
use crate::finger::construct::{FingerIndex, FingerParams};
use crate::graph::adjacency::FlatAdj;

/// Random orthonormalized projection (r × m).
pub fn random_projection(m: usize, r: usize, seed: u64) -> Matrix {
    let mut p = Matrix::zeros(r, m);
    let mut rng = Pcg32::new(seed);
    for i in 0..r {
        for v in p.row_mut(i) {
            *v = rng.next_gaussian();
        }
    }
    // Gram–Schmidt (reuses linalg's internals indirectly: small copy here to
    // avoid exposing mgs publicly).
    for i in 0..r {
        for j in 0..i {
            let coef = dot(p.row(i), p.row(j));
            let pj = p.row(j).to_vec();
            for (k, v) in p.row_mut(i).iter_mut().enumerate() {
                *v -= coef * pj[k];
            }
        }
        let n = dot(p.row(i), p.row(i)).sqrt().max(1e-12);
        for v in p.row_mut(i) {
            *v /= n;
        }
    }
    p
}

/// Build a FINGER-shaped index whose projection is random (RPLSH) instead
/// of the SVD basis. `params.distribution_matching` toggles the "+DM"
/// series of Figure 6.
pub fn build_rplsh_index(data: &Matrix, adj: &FlatAdj, params: FingerParams) -> FingerIndex {
    let mut idx = FingerIndex::build(data, adj, params.clone());
    // Replace the basis with a random one and recompute all derived tables
    // by rebuilding through the same constructor path: cheapest correct way
    // is to rebuild with a swapped-in projection. FingerIndex::build derives
    // everything from `proj`, so we rebuild the derived tables here.
    let proj = random_projection(data.cols(), params.rank.min(data.cols()), params.seed ^ 0x5A5A);
    idx.rebuild_with_projection(data, adj, proj);
    idx
}

/// Sign-bit LSH: per-vector r sign bits packed in u64 words; angle
/// estimated as hamming · π / r.
pub struct SignLsh {
    pub proj: Matrix,
    pub rank: usize,
}

impl SignLsh {
    pub fn new(m: usize, r: usize, seed: u64) -> SignLsh {
        // Raw (non-orthonormalized) Gaussian hyperplanes: sign-LSH needs
        // independent random directions, and r may exceed m, where
        // orthonormalization would degenerate.
        let mut proj = Matrix::zeros(r, m);
        let mut rng = Pcg32::new(seed);
        for i in 0..r {
            for v in proj.row_mut(i) {
                *v = rng.next_gaussian();
            }
        }
        SignLsh { proj, rank: r }
    }

    pub fn encode(&self, x: &[f32]) -> Vec<u64> {
        let words = self.rank.div_ceil(64);
        let mut out = vec![0u64; words];
        for i in 0..self.rank {
            if dot(self.proj.row(i), x) >= 0.0 {
                out[i / 64] |= 1 << (i % 64);
            }
        }
        out
    }

    /// Estimated angle (radians) between the pre-images of two codes.
    pub fn angle(&self, a: &[u64], b: &[u64]) -> f32 {
        let ham: u32 = a.iter().zip(b).map(|(x, y)| (x ^ y).count_ones()).sum();
        ham as f32 * std::f32::consts::PI / self.rank as f32
    }

    /// Estimated cosine.
    pub fn cosine(&self, a: &[u64], b: &[u64]) -> f32 {
        self.angle(a, b).cos()
    }
}

impl FingerIndex {
    /// Recompute every projection-derived table under a new basis. Used by
    /// the RPLSH ablation; also exercised by tests to validate that
    /// construction is a pure function of (data, adj, proj). Parallelized
    /// per node/pair exactly like `FingerIndex::build` — keyed sampling
    /// streams and disjoint writes, so the result is identical for every
    /// `params.threads`.
    pub fn rebuild_with_projection(&mut self, data: &Matrix, adj: &FlatAdj, proj: Matrix) {
        use crate::core::distance::cosine;
        use crate::core::distance::norm_sq;
        use crate::core::threads::{parallel_for, parallel_map, resolve_threads, DisjointSlice};
        use crate::finger::construct::EDGE_SCALARS;
        let n = data.rows();
        let m = data.cols();
        let r = proj.rows();
        let old_stride = self.edge_stride(); // still the old rank's stride
        let threads = resolve_threads(self.params.threads);

        // Per-node P·c (disjoint rows, fanned out).
        let mut pc = vec![0.0f32; n * r];
        {
            let pcv = DisjointSlice::new(&mut pc);
            parallel_for(n, threads, |c| {
                let p = crate::finger::construct::project(&proj, data.row(c));
                // Safety: each worker writes only node c's private row.
                unsafe { pcv.slice_mut(c * r, r).copy_from_slice(&p) };
            });
        }

        // Per-edge blocks: `d_proj`/`||d_res||` are basis-independent and
        // carried over from the old blocks; the projected residual and its
        // norm are recomputed under the new basis. The rank (and therefore
        // the block stride) may change, so the table is rebuilt wholesale
        // — per node in parallel, since edge slots of distinct nodes are
        // disjoint.
        let slots = adj.total_slots();
        let new_stride = r + EDGE_SCALARS;
        let mut edge = vec![0.0f32; slots * new_stride];
        {
            let ev = DisjointSlice::new(&mut edge);
            let this = &*self;
            parallel_for(n, threads, |ci| {
                let c = ci as u32;
                let xc = data.row(ci);
                let csq = this.c_sqnorm[ci].max(1e-12);
                for (j, &d) in adj.neighbors(c).iter().enumerate() {
                    let slot = adj.edge_slot(c, j);
                    let xd = data.row(d as usize);
                    let t = dot(xc, xd) / csq;
                    let mut dres = vec![0.0f32; m];
                    for k in 0..m {
                        dres[k] = xd[k] - t * xc[k];
                    }
                    let p = crate::finger::construct::project(&proj, &dres);
                    // Safety: slots of distinct nodes never overlap.
                    let b = unsafe { ev.slice_mut(slot * new_stride, new_stride) };
                    b[0] = this.edge[slot * old_stride];
                    b[1] = this.edge[slot * old_stride + 1];
                    b[2] = norm_sq(&p).sqrt();
                    b[EDGE_SCALARS..].copy_from_slice(&p);
                }
            });
        }
        self.rank = r;
        self.proj = proj;
        self.pc = pc;
        self.edge = edge;

        // Refit distribution matching under the new basis: pair picks come
        // from (seed^0x77, node)-keyed streams, cosines fan out per pair.
        let refit_seed = self.params.seed ^ 0x77;
        let mut pairs: Vec<(u32, u32, u32)> = Vec::new();
        for c in 0..n as u32 {
            let nbs = adj.neighbors(c);
            if nbs.len() < 2 {
                continue;
            }
            let (i, j2) = crate::finger::construct::sample_pair(refit_seed, c, nbs.len());
            pairs.push((c, nbs[i], nbs[j2]));
        }
        let this = &*self;
        let xy: Vec<(f32, f32)> = parallel_map(pairs.len(), threads, |pi| {
            let (c, d, dp) = pairs[pi];
            let xc = data.row(c as usize);
            let csq = this.c_sqnorm[c as usize].max(1e-12);
            let resid = |d: u32| -> Vec<f32> {
                let xd = data.row(d as usize);
                let t = dot(xc, xd) / csq;
                xd.iter().zip(xc).map(|(&a, &b)| a - t * b).collect()
            };
            let rd = resid(d);
            let rdp = resid(dp);
            (
                cosine(&rd, &rdp),
                cosine(
                    &crate::finger::construct::project(&this.proj, &rd),
                    &crate::finger::construct::project(&this.proj, &rdp),
                ),
            )
        });
        let xs: Vec<f32> = xy.iter().map(|p| p.0).collect();
        let ys: Vec<f32> = xy.iter().map(|p| p.1).collect();
        self.matching = crate::finger::construct::fit_matching(&xs, &ys, &self.params);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::Metric;
    use crate::data::synth::tiny;
    use crate::graph::hnsw::{Hnsw, HnswParams};

    #[test]
    fn random_projection_orthonormal() {
        let p = random_projection(32, 8, 3);
        for i in 0..8 {
            for j in 0..8 {
                let d = dot(p.row(i), p.row(j));
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-4, "({i},{j})={d}");
            }
        }
    }

    #[test]
    fn svd_beats_random_projection_on_low_rank_data() {
        // The core claim of the ablation (Fig. 6): FINGER's data-aware basis
        // estimates residual cosines better than RPLSH at equal rank.
        let ds = tiny(81, 600, 48, Metric::L2);
        let h = Hnsw::build(&ds.data, HnswParams { m: 8, ef_construction: 60, ..Default::default() });
        let params = FingerParams { rank: 8, ..Default::default() };
        let finger = crate::finger::construct::FingerIndex::build(&ds.data, &h.base, params.clone());
        let rplsh = build_rplsh_index(&ds.data, &h.base, params);
        assert!(
            finger.matching.correlation > rplsh.matching.correlation,
            "finger corr {} vs rplsh corr {}",
            finger.matching.correlation,
            rplsh.matching.correlation
        );
    }

    #[test]
    fn sign_lsh_estimates_angles() {
        let mut rng = Pcg32::new(5);
        let lsh = SignLsh::new(16, 256, 9);
        let mut errs = Vec::new();
        for _ in 0..200 {
            let a: Vec<f32> = (0..16).map(|_| rng.next_gaussian()).collect();
            let b: Vec<f32> = (0..16).map(|_| rng.next_gaussian()).collect();
            let true_cos = crate::core::distance::cosine(&a, &b);
            let est = lsh.cosine(&lsh.encode(&a), &lsh.encode(&b));
            errs.push((true_cos - est).abs());
        }
        let mean_err = crate::core::stats::mean(&errs);
        assert!(mean_err < 0.12, "mean |cos err| = {mean_err}");
    }

    #[test]
    fn sign_lsh_identical_vectors_zero_angle() {
        let lsh = SignLsh::new(8, 64, 1);
        let x = vec![1.0f32, -2.0, 3.0, 0.5, -0.25, 1.5, -1.0, 2.0];
        let c = lsh.encode(&x);
        assert_eq!(lsh.angle(&c, &c), 0.0);
        assert!((lsh.cosine(&c, &c) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn rebuild_is_pure_function_of_projection() {
        let ds = tiny(82, 200, 16, Metric::L2);
        let h = Hnsw::build(&ds.data, HnswParams { m: 6, ef_construction: 30, ..Default::default() });
        let params = FingerParams { rank: 8, ..Default::default() };
        let base = crate::finger::construct::FingerIndex::build(&ds.data, &h.base, params.clone());
        let mut rebuilt = crate::finger::construct::FingerIndex::build(&ds.data, &h.base, params);
        let proj = base.proj.clone();
        rebuilt.rebuild_with_projection(&ds.data, &h.base, proj);
        // Same projection -> identical edge blocks (scalars carried over,
        // projected residuals recomputed to the same values).
        assert_eq!(base.edge.len(), rebuilt.edge.len());
        for (a, b) in base.edge.iter().zip(&rebuilt.edge) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
