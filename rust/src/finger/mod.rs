//! The paper's contribution: FINGER index construction (Algorithm 2), the
//! approximate distance (Algorithm 3), the screened greedy search
//! (Algorithm 4), and the RPLSH ablation baseline.

pub mod approx;
pub mod construct;
pub mod ip;
pub mod rplsh;
pub mod search;

pub use approx::{approx_dist_sq, QueryCenter, QueryState};
pub use construct::{FingerIndex, FingerParams, MatchParams};
pub use search::{finger_beam_search, FingerHnsw};
