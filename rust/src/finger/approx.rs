//! Algorithm 3: the FINGER approximate distance, scalar hot path.
//!
//! Per expanded center `c` the query-side quantities are computed once
//! (`QueryCenter::new`), then each neighbor edge costs one r-dimensional
//! dot product plus a handful of scalar ops — the paper's m-dim -> r-dim
//! reduction. The per-edge data lives in `FingerIndex` as one interleaved
//! block per edge slot (`[d_proj, ||d_res||, ||P d_res||, P·d_res]`), and
//! a node's out-edges occupy consecutive slots — so screening one
//! expansion is a single contiguous forward stream, not four parallel
//! array walks (DESIGN.md §4).

use crate::core::distance::dot;
use crate::finger::construct::FingerIndex;

/// Query-side state for the whole search (computed once per query).
pub struct QueryState {
    /// P·q (r floats).
    pub pq: Vec<f32>,
    /// ||q||^2.
    pub q_sqnorm: f32,
}

impl QueryState {
    pub fn new(index: &FingerIndex, q: &[f32]) -> QueryState {
        QueryState {
            pq: crate::finger::construct::project(&index.proj, q),
            q_sqnorm: crate::core::distance::norm_sq(q),
        }
    }
}

/// Upper bound on the projection rank, sized so `QueryCenter` fits on the
/// stack (the paper never goes past r = 48; Supplementary E).
pub const MAX_RANK: usize = 64;

/// Query-vs-center state, valid while expanding one center node c
/// (Supplementary G: everything here comes from already-known scalars).
/// Perf note (EXPERIMENTS.md §Perf): `pq_res` is a fixed inline array, not
/// a Vec — one `QueryCenter` is built per node expansion, and the heap
/// allocation showed up in the search profile.
pub struct QueryCenter {
    /// Signed projection length of q onto c.
    pub q_proj: f32,
    /// ||q_res||.
    pub q_res_norm: f32,
    /// P·q_res (first `rank` entries valid).
    pub pq_res: [f32; MAX_RANK],
    /// ||P q_res||.
    pub pq_res_norm: f32,
}

impl QueryCenter {
    /// `dist_qc_sq` is the already-computed ||q - c||^2 (the center was
    /// popped from the candidate queue, so its exact distance is known).
    pub fn new(index: &FingerIndex, qs: &QueryState, c: u32, dist_qc_sq: f32) -> QueryCenter {
        let r = index.rank;
        debug_assert!(r <= MAX_RANK);
        let ci = c as usize;
        let c_sq = index.c_sqnorm[ci].max(1e-12);
        let c_n = index.c_norm[ci].max(1e-12);
        // q^T c = (||q||^2 + ||c||^2 - ||q-c||^2) / 2
        let qtc = 0.5 * (qs.q_sqnorm + index.c_sqnorm[ci] - dist_qc_sq);
        let t_q = qtc / c_sq;
        let q_proj = qtc / c_n;
        let q_res_sq = (qs.q_sqnorm - q_proj * q_proj).max(0.0);
        // P q_res = P q - t_q * P c
        let pc = &index.pc[ci * r..(ci + 1) * r];
        let mut pq_res = [0.0f32; MAX_RANK];
        let mut norm_sq = 0.0f32;
        for k in 0..r {
            let v = qs.pq[k] - t_q * pc[k];
            pq_res[k] = v;
            norm_sq += v * v;
        }
        QueryCenter {
            q_proj,
            q_res_norm: q_res_sq.sqrt(),
            pq_res,
            pq_res_norm: norm_sq.sqrt(),
        }
    }
}

/// Approximate squared distance for the edge at `slot` (Algorithm 3).
/// One contiguous block read: the three scalars and the projected
/// residual arrive on the same cache lines.
#[inline]
pub fn approx_dist_sq(index: &FingerIndex, qc: &QueryCenter, slot: usize) -> f32 {
    let r = index.rank;
    let b = index.edge_block(slot);
    let (dp, dn, pn) = (b[0], b[1], b[2]);
    let pres = &b[crate::finger::construct::EDGE_SCALARS..];
    let denom = (qc.pq_res_norm * pn).max(1e-12);
    let t_hat = dot(&qc.pq_res[..r], pres) / denom;
    let m = &index.matching;
    let t = (t_hat - m.mu_hat) * (m.sigma / m.sigma_hat.max(1e-12)) + m.mu + m.eps;
    let proj_term = qc.q_proj - dp;
    proj_term * proj_term + qc.q_res_norm * qc.q_res_norm + dn * dn
        - 2.0 * qc.q_res_norm * dn * t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::{l2_sq, Metric};
    use crate::core::matrix::Matrix;
    use crate::data::synth::tiny;
    use crate::finger::construct::{FingerIndex, FingerParams};
    use crate::graph::hnsw::{Hnsw, HnswParams};

    /// Full-rank FINGER with identity matching must reproduce exact
    /// distances (Eq. 2 is an identity when P captures everything).
    #[test]
    fn full_rank_identity_matching_is_exact() {
        let ds = tiny(61, 200, 8, Metric::L2);
        let h = Hnsw::build(&ds.data, HnswParams { m: 6, ef_construction: 40, ..Default::default() });
        let f = FingerIndex::build(
            &ds.data,
            &h.base,
            FingerParams {
                rank: 8, // == dim: lossless projection
                distribution_matching: false,
                error_correction: false,
                ..Default::default()
            },
        );
        let q = ds.queries.row(0);
        let qs = QueryState::new(&f, q);
        let mut checked = 0;
        for c in 0..ds.data.rows() as u32 {
            let dqc = l2_sq(q, ds.data.row(c as usize));
            let qc = QueryCenter::new(&f, &qs, c, dqc);
            for (j, &d) in h.base.neighbors(c).iter().enumerate() {
                let slot = h.base.edge_slot(c, j);
                let approx = approx_dist_sq(&f, &qc, slot);
                let exact = l2_sq(q, ds.data.row(d as usize));
                assert!(
                    (approx - exact).abs() < 2e-2 * (1.0 + exact),
                    "edge ({c},{d}): approx {approx} exact {exact}"
                );
                checked += 1;
            }
        }
        assert!(checked > 100);
    }

    /// Low-rank approximation should correlate strongly with exact
    /// distances on clustered data.
    #[test]
    fn low_rank_approximation_correlates() {
        let ds = tiny(62, 400, 32, Metric::L2);
        let h = Hnsw::build(&ds.data, HnswParams { m: 8, ef_construction: 60, ..Default::default() });
        let f = FingerIndex::build(&ds.data, &h.base, FingerParams { rank: 8, ..Default::default() });
        let mut approxs = Vec::new();
        let mut exacts = Vec::new();
        for qi in 0..ds.queries.rows().min(8) {
            let q = ds.queries.row(qi);
            let qs = QueryState::new(&f, q);
            for c in (0..ds.data.rows() as u32).step_by(17) {
                let dqc = l2_sq(q, ds.data.row(c as usize));
                let qc = QueryCenter::new(&f, &qs, c, dqc);
                for (j, &d) in h.base.neighbors(c).iter().enumerate() {
                    let slot = h.base.edge_slot(c, j);
                    approxs.push(approx_dist_sq(&f, &qc, slot));
                    exacts.push(l2_sq(q, ds.data.row(d as usize)));
                }
            }
        }
        let corr = crate::core::stats::pearson(&approxs, &exacts);
        assert!(corr > 0.9, "correlation = {corr}");
    }

    /// QueryCenter scalars must agree with direct computation.
    #[test]
    fn query_center_scalars_match_direct() {
        let ds = tiny(63, 100, 16, Metric::L2);
        let h = Hnsw::build(&ds.data, HnswParams { m: 6, ef_construction: 30, ..Default::default() });
        let f = FingerIndex::build(&ds.data, &h.base, FingerParams { rank: 16, ..Default::default() });
        let q = ds.queries.row(3);
        let c = 7u32;
        let xc = ds.data.row(c as usize);
        let dqc = l2_sq(q, xc);
        let qs = QueryState::new(&f, q);
        let qc = QueryCenter::new(&f, &qs, c, dqc);
        // Direct decomposition
        let csq = crate::core::distance::norm_sq(xc);
        let t = crate::core::distance::dot(q, xc) / csq;
        let qp_direct = t * csq.sqrt();
        let qres: Vec<f32> = q.iter().zip(xc).map(|(&a, &b)| a - t * b).collect();
        assert!((qc.q_proj - qp_direct).abs() < 1e-3 * (1.0 + qp_direct.abs()));
        assert!(
            (qc.q_res_norm - crate::core::distance::norm(&qres)).abs() < 1e-3,
            "{} vs {}",
            qc.q_res_norm,
            crate::core::distance::norm(&qres)
        );
    }

    #[test]
    fn zero_query_is_stable() {
        let ds = tiny(64, 100, 8, Metric::L2);
        let h = Hnsw::build(&ds.data, HnswParams { m: 6, ef_construction: 30, ..Default::default() });
        let f = FingerIndex::build(&ds.data, &h.base, FingerParams { rank: 8, ..Default::default() });
        let q = vec![0.0f32; 8];
        let qs = QueryState::new(&f, &q);
        let dqc = l2_sq(&q, ds.data.row(0));
        let qc = QueryCenter::new(&f, &qs, 0, dqc);
        for (j, _) in h.base.neighbors(0).iter().enumerate() {
            let slot = h.base.edge_slot(0, j);
            assert!(approx_dist_sq(&f, &qc, slot).is_finite());
        }
    }

    /// Matrix sanity for the helper used everywhere.
    #[test]
    fn project_is_linear() {
        let proj = Matrix::from_rows(&[vec![1.0, 0.0, 0.0], vec![0.0, 2.0, 0.0]]);
        let p = crate::finger::construct::project(&proj, &[3.0, 4.0, 5.0]);
        assert_eq!(p, vec![3.0, 8.0]);
    }
}
