//! FINGER index construction — Algorithm 2 of the paper.
//!
//! Given an existing search graph G = (D, E):
//!  1. For each node c, compute residual vectors of its neighbors w.r.t. c
//!     and collect a subsample into D_res.
//!  2. P = top-r left singular basis of D_res (Prop. 3.1, via
//!     `core::linalg::finger_projection`).
//!  3. Sample neighbor pairs (d, d') per node; X = true residual cosines,
//!     Y = rank-r approximated cosines. Fit Gaussians: (mu, sigma) from X,
//!     (mu_hat, sigma_hat) from Y, and the mean-L1 error-correction term
//!     eps = mean |(Y_i - mu_hat) sigma/sigma_hat + mu - X_i|.
//!  4. Precompute per-node (||c||, ||c||^2, P c) and per-edge
//!     (d_proj, ||d_res||, P d_res, ||P d_res||) tables, laid out
//!     structure-of-arrays on the base graph's stable edge slots.

use crate::core::distance::{cosine, dot, norm_sq};
use crate::core::linalg::finger_projection;
use crate::core::matrix::Matrix;
use crate::core::rng::Pcg32;
use crate::core::stats;
use crate::core::threads::{parallel_for, parallel_map, resolve_threads, DisjointSlice};
use crate::graph::adjacency::FlatAdj;

/// Construction parameters.
#[derive(Clone, Debug)]
pub struct FingerParams {
    /// Rank r of the projection (paper: multiples of 8 for SIMD).
    pub rank: usize,
    /// Cap on residual vectors fed to the SVD (uniform subsample).
    pub max_svd_samples: usize,
    /// Enable distribution matching (ablation: Figure 6 "no-DM").
    pub distribution_matching: bool,
    /// Enable the additive mean-L1 error-correction term.
    pub error_correction: bool,
    pub seed: u64,
    /// Training worker threads (0 = `FINGER_THREADS`/auto). Training is
    /// per-node/per-pair parallel with a fixed sampling plan, so the
    /// built index is bitwise identical for every value; never persisted.
    pub threads: usize,
}

impl Default for FingerParams {
    fn default() -> Self {
        Self {
            rank: 16,
            max_svd_samples: 8192,
            distribution_matching: true,
            error_correction: true,
            seed: 42,
            threads: 0,
        }
    }
}

/// Per-node neighbor-pair pick for training, drawn from a private PCG
/// stream keyed on (seed, node) — independent of visit order, so the
/// sampling plan is the same no matter how the work is scheduled. Shared
/// with the RPLSH rebuild so the two sampling protocols cannot drift.
pub(crate) fn sample_pair(seed: u64, c: u32, n_neighbors: usize) -> (usize, usize) {
    let mut rng = Pcg32::with_stream(seed, c as u64);
    let i = rng.gen_range(n_neighbors);
    let mut j = rng.gen_range(n_neighbors);
    while j == i {
        j = rng.gen_range(n_neighbors);
    }
    (i, j)
}

/// Distribution-matching parameters (Algorithm 2 outputs).
#[derive(Clone, Copy, Debug, Default)]
pub struct MatchParams {
    pub mu: f32,
    pub sigma: f32,
    pub mu_hat: f32,
    pub sigma_hat: f32,
    pub eps: f32,
    /// Pearson correlation between X and Y — Supplementary E's rank-
    /// selection diagnostic.
    pub correlation: f32,
}

/// Scalar lanes preceding the projected residual inside one interleaved
/// edge block: `[d_proj, ||d_res||, ||P d_res||]`.
pub const EDGE_SCALARS: usize = 3;

/// The built FINGER side-index over a base graph.
pub struct FingerIndex {
    pub rank: usize,
    /// r × m projection (rows orthonormal).
    pub proj: Matrix,
    pub matching: MatchParams,
    pub params: FingerParams,

    // Per-node tables (length n).
    pub c_norm: Vec<f32>,
    pub c_sqnorm: Vec<f32>,
    /// P·c, n × r row-major.
    pub pc: Vec<f32>,

    /// Per-edge table aligned with the base adjacency's edge slots, one
    /// interleaved block of `rank + EDGE_SCALARS` floats per slot:
    /// `[d_proj, ||d_res||, ||P d_res||, P·d_res[0..rank]]`.
    /// A node's out-edges occupy consecutive slots, so Algorithm 3
    /// screening of an expansion is one contiguous forward stream instead
    /// of four parallel array walks (the old `edge_proj`/`edge_res_norm`/
    /// `edge_pres_norm`/`edge_pres` quadruple). The on-disk format still
    /// stores the four arrays separately (`data::persist::save_finger`),
    /// so v3–v5 bundles are unaffected.
    pub edge: Vec<f32>,
}

impl FingerIndex {
    /// Algorithm 2. `adj` is the base-layer adjacency of any search graph.
    ///
    /// Training is parallel and deterministic: the sampling plan (one
    /// neighbor pair per node, a strided SVD subsample of those pairs) is
    /// fixed up front from per-node keyed PCG streams, after which every
    /// residual, cosine, per-node table row, and per-edge block is an
    /// independent pure function fanned out over `params.threads` workers
    /// — the result is bitwise identical for every thread count.
    pub fn build(data: &Matrix, adj: &FlatAdj, params: FingerParams) -> FingerIndex {
        let n = data.rows();
        let m = data.cols();
        let r = params.rank.min(m);
        let threads = resolve_threads(params.threads);

        // ---- Pass 1: the sampling plan — one neighbor pair per node
        // with 2+ neighbors, drawn from (seed, node)-keyed streams.
        let mut pair_nodes: Vec<(u32, u32, u32)> = Vec::new(); // (c, d, d')
        for c in 0..n as u32 {
            let nbs = adj.neighbors(c);
            if nbs.len() < 2 {
                continue;
            }
            let (i, j) = sample_pair(params.seed, c, nbs.len());
            pair_nodes.push((c, nbs[i], nbs[j]));
        }

        // SVD pool: all pair residuals when they fit, else an evenly
        // strided subsample of them (deterministic, order-free).
        let take = pair_nodes.len().min(params.max_svd_samples);
        let sample_rows: Vec<Vec<f32>> = parallel_map(take, threads, |s| {
            let (c, d, _) = pair_nodes[s * pair_nodes.len() / take.max(1)];
            residual(data, c, d)
        });
        let mut res_samples = Matrix::zeros(0, 0);
        for row in &sample_rows {
            res_samples.push_row(row);
        }
        if res_samples.rows() == 0 {
            // Degenerate graph (no node with 2+ neighbors): fall back to
            // random rows as "residuals" so we still produce a basis.
            let mut rng = Pcg32::new(params.seed);
            for _ in 0..r.max(8) {
                let i = rng.gen_range(n);
                res_samples.push_row(data.row(i));
            }
        }

        // ---- SVD: top-r basis of the residual pool (Prop. 3.1).
        let eb = finger_projection(&res_samples, r, params.seed ^ 0xABCD);
        let proj = eb.basis; // r × m

        // ---- Distribution matching: X true cosines, Y projected cosines
        // (independent per pair — fanned out).
        let xy: Vec<(f32, f32)> = parallel_map(pair_nodes.len(), threads, |pi| {
            let (c, d, dp) = pair_nodes[pi];
            let rd = residual(data, c, d);
            let rdp = residual(data, c, dp);
            let pd = project(&proj, &rd);
            let pdp = project(&proj, &rdp);
            (cosine(&rd, &rdp), cosine(&pd, &pdp))
        });
        let xs: Vec<f32> = xy.iter().map(|p| p.0).collect();
        let ys: Vec<f32> = xy.iter().map(|p| p.1).collect();
        let matching = fit_matching(&xs, &ys, &params);

        // ---- Per-node and per-edge precomputation: disjoint writes per
        // node (a node's edge slots are contiguous), fanned out.
        let mut c_norm = vec![0.0f32; n];
        let mut c_sqnorm = vec![0.0f32; n];
        let mut pc = vec![0.0f32; n * r];
        {
            let cn = DisjointSlice::new(&mut c_norm);
            let cs = DisjointSlice::new(&mut c_sqnorm);
            let pcv = DisjointSlice::new(&mut pc);
            parallel_for(n, threads, |c| {
                let x = data.row(c);
                let sq = norm_sq(x);
                let p = project(&proj, x);
                // Safety: each worker writes only node c's scalar cells
                // and its private pc row.
                unsafe {
                    cs.write(c, sq);
                    cn.write(c, sq.sqrt());
                    pcv.slice_mut(c * r, r).copy_from_slice(&p);
                }
            });
        }

        let slots = adj.total_slots();
        let stride = r + EDGE_SCALARS;
        let mut edge = vec![0.0f32; slots * stride];
        {
            let ev = DisjointSlice::new(&mut edge);
            parallel_for(n, threads, |ci| {
                let c = ci as u32;
                let xc = data.row(ci);
                let csq = c_sqnorm[ci].max(1e-12);
                let cn = c_norm[ci].max(1e-12);
                for (j, &d) in adj.neighbors(c).iter().enumerate() {
                    let slot = adj.edge_slot(c, j);
                    let xd = data.row(d as usize);
                    let t = dot(xc, xd) / csq; // projection coefficient
                    // d_res = d - t*c
                    let mut dres = vec![0.0f32; m];
                    for k in 0..m {
                        dres[k] = xd[k] - t * xc[k];
                    }
                    let p = project(&proj, &dres);
                    // Safety: edge slots of distinct nodes are disjoint.
                    let b = unsafe { ev.slice_mut(slot * stride, stride) };
                    b[0] = t * cn; // signed length along c
                    b[1] = norm_sq(&dres).sqrt();
                    b[2] = norm_sq(&p).sqrt();
                    b[EDGE_SCALARS..].copy_from_slice(&p);
                }
            });
        }

        FingerIndex {
            rank: r,
            proj,
            matching,
            params,
            c_norm,
            c_sqnorm,
            pc,
            edge,
        }
    }

    /// Floats per interleaved edge block.
    #[inline]
    pub fn edge_stride(&self) -> usize {
        self.rank + EDGE_SCALARS
    }

    /// Total edge slots covered by the table.
    #[inline]
    pub fn edge_slots(&self) -> usize {
        self.edge.len() / self.edge_stride()
    }

    /// The whole interleaved block of `slot` (Algorithm 3 reads this once).
    #[inline]
    pub fn edge_block(&self, slot: usize) -> &[f32] {
        let s = self.edge_stride();
        &self.edge[slot * s..(slot + 1) * s]
    }

    /// Signed projection length of d onto c: (c·d/||c||).
    #[inline]
    pub fn edge_proj(&self, slot: usize) -> f32 {
        self.edge[slot * self.edge_stride()]
    }

    /// ||d_res||.
    #[inline]
    pub fn edge_res_norm(&self, slot: usize) -> f32 {
        self.edge[slot * self.edge_stride() + 1]
    }

    /// ||P d_res||.
    #[inline]
    pub fn edge_pres_norm(&self, slot: usize) -> f32 {
        self.edge[slot * self.edge_stride() + 2]
    }

    /// P·d_res (rank floats).
    #[inline]
    pub fn edge_pres(&self, slot: usize) -> &[f32] {
        &self.edge_block(slot)[EDGE_SCALARS..]
    }

    /// Overwrite one edge block; `||P d_res||` is derived from `pres`.
    pub fn set_edge(&mut self, slot: usize, proj_len: f32, res_norm: f32, pres: &[f32]) {
        debug_assert_eq!(pres.len(), self.rank);
        let s = self.edge_stride();
        let b = &mut self.edge[slot * s..(slot + 1) * s];
        b[0] = proj_len;
        b[1] = res_norm;
        b[2] = norm_sq(pres).sqrt();
        b[EDGE_SCALARS..].copy_from_slice(pres);
    }

    /// Overwrite only the projected-residual part of a block (the RPLSH
    /// basis swap: `d_proj`/`||d_res||` are basis-independent).
    pub fn set_edge_pres(&mut self, slot: usize, pres: &[f32]) {
        debug_assert_eq!(pres.len(), self.rank);
        let s = self.edge_stride();
        let b = &mut self.edge[slot * s..(slot + 1) * s];
        b[2] = norm_sq(pres).sqrt();
        b[EDGE_SCALARS..].copy_from_slice(pres);
    }

    /// Online insertion, part 1: extend the per-node tables for a freshly
    /// appended row `id` and reserve its `base_cap` per-edge slots (they
    /// land at the array tails because `FlatAdj::add_node` appends slots,
    /// so every existing slot keeps its meaning). The projection basis and
    /// matching parameters are kept as trained — they are re-fit from the
    /// live set at the next compaction.
    pub fn append_node(&mut self, data: &Matrix, id: u32, base_cap: usize) {
        let x = data.row(id as usize);
        let sq = norm_sq(x);
        self.c_sqnorm.push(sq);
        self.c_norm.push(sq.sqrt());
        self.pc.extend(project(&self.proj, x));
        let stride = self.edge_stride();
        self.edge.resize(self.edge.len() + base_cap * stride, 0.0);
    }

    /// Online insertion, part 2: recompute the per-edge tables for every
    /// current edge of `c` on the base layer — called for each node whose
    /// neighbor list the graph insertion rewired (stale slots would
    /// otherwise mis-screen). Mirrors the build-time per-edge pass.
    pub fn refresh_node_edges(&mut self, data: &Matrix, adj: &FlatAdj, c: u32) {
        let m = data.cols();
        let xc = data.row(c as usize);
        let csq = self.c_sqnorm[c as usize].max(1e-12);
        let cn = self.c_norm[c as usize].max(1e-12);
        for (j, &d) in adj.neighbors(c).iter().enumerate() {
            let slot = adj.edge_slot(c, j);
            let xd = data.row(d as usize);
            let t = dot(xc, xd) / csq;
            let mut dres = vec![0.0f32; m];
            for k in 0..m {
                dres[k] = xd[k] - t * xc[k];
            }
            let p = project(&self.proj, &dres);
            self.set_edge(slot, t * cn, norm_sq(&dres).sqrt(), &p);
        }
    }

    /// Additional memory footprint in bytes (Table 1's "(r+2)·|E|·4" plus
    /// per-node tables).
    pub fn nbytes(&self) -> usize {
        4 * (self.c_norm.len() + self.c_sqnorm.len() + self.pc.len() + self.edge.len())
    }
}

/// Residual of `d` w.r.t. center `c` (Eq. 1).
fn residual(data: &Matrix, c: u32, d: u32) -> Vec<f32> {
    let xc = data.row(c as usize);
    let xd = data.row(d as usize);
    let csq = norm_sq(xc).max(1e-12);
    let t = dot(xc, xd) / csq;
    xd.iter().zip(xc).map(|(&dv, &cv)| dv - t * cv).collect()
}

/// P·x for the r × m projection.
pub fn project(proj: &Matrix, x: &[f32]) -> Vec<f32> {
    (0..proj.rows()).map(|i| dot(proj.row(i), x)).collect()
}

/// Fit the Gaussian matching parameters from true (X) and approximated (Y)
/// cosine samples — Algorithm 2 lines 8-11.
pub fn fit_matching(xs: &[f32], ys: &[f32], params: &FingerParams) -> MatchParams {
    if xs.is_empty() {
        return MatchParams {
            mu: 0.0,
            sigma: 1.0,
            mu_hat: 0.0,
            sigma_hat: 1.0,
            eps: 0.0,
            correlation: 0.0,
        };
    }
    let (mu, sigma) = (stats::mean(xs), stats::stddev(xs).max(1e-6));
    let (mu_hat, sigma_hat) = (stats::mean(ys), stats::stddev(ys).max(1e-6));
    let correlation = stats::pearson(xs, ys);
    let (mu, sigma, mu_hat, sigma_hat) = if params.distribution_matching {
        (mu, sigma, mu_hat, sigma_hat)
    } else {
        (0.0, 1.0, 0.0, 1.0) // identity transform
    };
    let eps = if params.error_correction {
        let n = xs.len() as f32;
        xs.iter()
            .zip(ys)
            .map(|(&x, &y)| ((y - mu_hat) * (sigma / sigma_hat) + mu - x).abs())
            .sum::<f32>()
            / n
    } else {
        0.0
    };
    MatchParams {
        mu,
        sigma,
        mu_hat,
        sigma_hat,
        eps,
        correlation,
    }
}

/// Supplementary E's rule of thumb: grow r in steps of 8 until the X/Y
/// correlation exceeds `threshold` (default 0.7). Returns (rank, corr)
/// pairs tried and the chosen index.
pub fn select_rank(
    data: &Matrix,
    adj: &FlatAdj,
    threshold: f32,
    max_rank: usize,
    seed: u64,
) -> (Vec<(usize, f32)>, usize) {
    let mut tried = Vec::new();
    let mut rank = 8;
    loop {
        let idx = FingerIndex::build(
            data,
            adj,
            FingerParams {
                rank,
                seed,
                ..Default::default()
            },
        );
        tried.push((rank, idx.matching.correlation));
        if idx.matching.correlation >= threshold || rank >= max_rank {
            break;
        }
        rank += 8;
    }
    let chosen = tried.len() - 1;
    (tried, chosen)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::Metric;
    use crate::data::synth::tiny;
    use crate::graph::hnsw::{Hnsw, HnswParams};

    fn build_small() -> (crate::data::synth::Dataset, Hnsw, FingerIndex) {
        let ds = tiny(51, 400, 32, Metric::L2);
        let h = Hnsw::build(&ds.data, HnswParams { m: 8, ef_construction: 60, ..Default::default() });
        let f = FingerIndex::build(&ds.data, &h.base, FingerParams { rank: 8, ..Default::default() });
        (ds, h, f)
    }

    #[test]
    fn tables_have_expected_shapes() {
        let (ds, h, f) = build_small();
        let n = ds.data.rows();
        assert_eq!(f.c_norm.len(), n);
        assert_eq!(f.pc.len(), n * f.rank);
        assert_eq!(f.edge_slots(), h.base.total_slots());
        assert_eq!(f.edge.len(), h.base.total_slots() * (f.rank + EDGE_SCALARS));
        assert_eq!(f.edge_pres(0).len(), f.rank);
    }

    #[test]
    fn projection_rows_orthonormal() {
        let (_, _, f) = build_small();
        for i in 0..f.rank {
            for j in 0..f.rank {
                let d = dot(f.proj.row(i), f.proj.row(j));
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-2, "({i},{j}) = {d}");
            }
        }
    }

    #[test]
    fn edge_tables_consistent_with_decomposition() {
        // For every edge (c, d): ||d||^2 == dp^2 + ||d_res||^2 (orthogonal
        // decomposition), and P d_res norm <= d_res norm.
        let (ds, h, f) = build_small();
        for c in 0..ds.data.rows() as u32 {
            for (j, &d) in h.base.neighbors(c).iter().enumerate() {
                let slot = h.base.edge_slot(c, j);
                let dsq = norm_sq(ds.data.row(d as usize));
                let recon = f.edge_proj(slot).powi(2) + f.edge_res_norm(slot).powi(2);
                assert!(
                    (dsq - recon).abs() < 1e-2 * (1.0 + dsq),
                    "edge ({c},{d}): {dsq} vs {recon}"
                );
                assert!(f.edge_pres_norm(slot) <= f.edge_res_norm(slot) + 1e-3);
            }
        }
    }

    #[test]
    fn incremental_tables_satisfy_build_invariants() {
        use crate::core::matrix::Matrix;
        use crate::index::context::SearchContext;
        // Build over a prefix, stream the rest through the online path.
        let ds = tiny(55, 300, 16, Metric::L2);
        let mut m = Matrix::zeros(0, 16);
        for i in 0..250 {
            m.push_row(ds.data.row(i));
        }
        let mut store = crate::core::store::VectorStore::from_matrix(&m);
        let mut h = Hnsw::build_with_store(&store, HnswParams { m: 8, ef_construction: 40, ..Default::default() });
        let mut f = FingerIndex::build(&m, &h.base, FingerParams { rank: 8, ..Default::default() });
        let mut ctx = SearchContext::new();
        for i in 250..300 {
            m.push_row(ds.data.row(i));
            store.push_row(ds.data.row(i));
            let touched = h.insert_node(&store, i as u32, &mut ctx);
            f.append_node(&m, i as u32, h.base.cap());
            for &u in &touched {
                f.refresh_node_edges(&m, &h.base, u);
            }
        }
        assert_eq!(f.c_norm.len(), 300);
        assert_eq!(f.pc.len(), 300 * f.rank);
        assert_eq!(f.edge_slots(), h.base.total_slots());
        // Orthogonal decomposition must hold on every edge — a slot left
        // stale by a rewired-but-unrefreshed list would break it, because
        // the stored values belong to the old neighbor.
        for c in 0..300u32 {
            for (j, &d) in h.base.neighbors(c).iter().enumerate() {
                let slot = h.base.edge_slot(c, j);
                let dsq = norm_sq(m.row(d as usize));
                let recon = f.edge_proj(slot).powi(2) + f.edge_res_norm(slot).powi(2);
                assert!(
                    (dsq - recon).abs() < 1e-2 * (1.0 + dsq),
                    "stale edge ({c},{d}): {dsq} vs {recon}"
                );
                assert!(f.edge_pres_norm(slot) <= f.edge_res_norm(slot) + 1e-3);
            }
        }
    }

    #[test]
    fn matching_params_sane() {
        let (_, _, f) = build_small();
        let m = f.matching;
        assert!(m.sigma > 0.0 && m.sigma_hat > 0.0);
        assert!(m.mu.abs() < 1.0 && m.mu_hat.abs() < 1.0);
        assert!(m.eps >= 0.0 && m.eps < 1.0);
        assert!(m.correlation > 0.2, "corr = {}", m.correlation);
    }

    #[test]
    fn no_dm_yields_identity_transform() {
        let ds = tiny(52, 300, 16, Metric::L2);
        let h = Hnsw::build(&ds.data, HnswParams { m: 8, ef_construction: 40, ..Default::default() });
        let f = FingerIndex::build(
            &ds.data,
            &h.base,
            FingerParams { rank: 8, distribution_matching: false, error_correction: false, ..Default::default() },
        );
        assert_eq!(f.matching.mu, 0.0);
        assert_eq!(f.matching.sigma, 1.0);
        assert_eq!(f.matching.eps, 0.0);
    }

    #[test]
    fn higher_rank_improves_correlation() {
        let ds = tiny(53, 500, 48, Metric::L2);
        let h = Hnsw::build(&ds.data, HnswParams { m: 8, ef_construction: 60, ..Default::default() });
        let f8 = FingerIndex::build(&ds.data, &h.base, FingerParams { rank: 8, ..Default::default() });
        let f32_ = FingerIndex::build(&ds.data, &h.base, FingerParams { rank: 32, ..Default::default() });
        assert!(
            f32_.matching.correlation >= f8.matching.correlation - 0.05,
            "r8 {} vs r32 {}",
            f8.matching.correlation,
            f32_.matching.correlation
        );
    }

    #[test]
    fn rank_selection_terminates() {
        let ds = tiny(54, 300, 32, Metric::L2);
        let h = Hnsw::build(&ds.data, HnswParams { m: 8, ef_construction: 40, ..Default::default() });
        let (tried, chosen) = select_rank(&ds.data, &h.base, 0.7, 32, 1);
        assert!(!tried.is_empty());
        assert!(chosen < tried.len());
        assert!(tried[chosen].0 <= 32);
    }
}
