//! Parse `artifacts/manifest.json` emitted by `python/compile/aot.py` —
//! names, shapes and output layouts of every AOT-compiled HLO module.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::core::error::{anyhow, Context, Result};
use crate::core::json::Json;

/// One tensor's static shape + dtype.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// kind-specific integers (batch, cands, dim, k, rank) when present.
    pub meta: BTreeMap<String, usize>,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn tensor_list(v: &Json) -> Result<Vec<TensorSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("expected array of tensors"))?
        .iter()
        .map(|t| {
            let shape = t
                .get("shape")
                .and_then(|s| s.as_arr())
                .ok_or_else(|| anyhow!("tensor missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            let dtype = t
                .get("dtype")
                .and_then(|d| d.as_str())
                .unwrap_or("f32")
                .to_string();
            Ok(TensorSpec { shape, dtype })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        let arts = root
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?;
        let mut artifacts = BTreeMap::new();
        for (name, v) in arts {
            let file = v
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow!("artifact {name} missing file"))?;
            let mut meta = BTreeMap::new();
            for key in ["batch", "cands", "dim", "k", "rank"] {
                if let Some(n) = v.get(key).and_then(|x| x.as_usize()) {
                    meta.insert(key.to_string(), n);
                }
            }
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    kind: v
                        .get("kind")
                        .and_then(|k| k.as_str())
                        .unwrap_or("unknown")
                        .to_string(),
                    file: dir.join(file),
                    inputs: tensor_list(v.get("inputs").unwrap_or(&Json::Arr(vec![])))?,
                    outputs: tensor_list(v.get("outputs").unwrap_or(&Json::Arr(vec![])))?,
                    meta,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    /// Find the first artifact matching a predicate.
    pub fn find(&self, pred: impl Fn(&ArtifactSpec) -> bool) -> Option<&ArtifactSpec> {
        self.artifacts.values().find(|a| pred(a))
    }

    /// Find a rerank artifact for the given data dimension.
    pub fn rerank_for_dim(&self, dim: usize) -> Option<&ArtifactSpec> {
        self.find(|a| a.kind == "rerank" && a.meta.get("dim") == Some(&dim))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        let json = r#"{"format":"hlo-text","artifacts":{
            "rerank_b4_c64_d32_k5":{"kind":"rerank","batch":4,"cands":64,"dim":32,"k":5,
              "file":"rerank_b4_c64_d32_k5.hlo.txt",
              "inputs":[{"shape":[4,32],"dtype":"float32"},{"shape":[64,32],"dtype":"float32"},{"shape":[64],"dtype":"float32"}],
              "outputs":[{"shape":[4,5],"dtype":"f32"},{"shape":[4,5],"dtype":"i32"}]}}}"#;
        std::fs::write(dir.join("manifest.json"), json).unwrap();
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join(format!("finger_manifest_{}", std::process::id()));
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let a = &m.artifacts["rerank_b4_c64_d32_k5"];
        assert_eq!(a.kind, "rerank");
        assert_eq!(a.meta["dim"], 32);
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0].shape, vec![4, 32]);
        assert_eq!(a.outputs[1].dtype, "i32");
        assert_eq!(a.inputs[0].numel(), 128);
        assert!(m.rerank_for_dim(32).is_some());
        assert!(m.rerank_for_dim(999).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = std::env::temp_dir().join("finger_manifest_missing_xyz");
        assert!(Manifest::load(&dir).is_err());
    }
}
