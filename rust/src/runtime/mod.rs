//! Runtime: PJRT client wrapping the `xla` crate — loads and executes the
//! AOT artifacts produced by `python/compile/aot.py`. Python never runs at
//! request time; the HLO text modules are self-contained.

pub mod engine;
pub mod manifest;
pub mod service;
pub mod xla_stub;

pub use engine::{default_artifacts_dir, Engine, Executable, RerankResult, PAD_SQNORM};
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
