//! Offline stub of the `xla` crate surface used by [`crate::runtime`].
//!
//! The build environment has no crates.io access and no `xla_extension`
//! shared library, so the PJRT client cannot exist here. This module
//! mirrors the exact API shape `engine.rs` consumes; every entry point
//! that would touch PJRT returns an error, which the callers already
//! handle gracefully (the rerank service reports itself unavailable and
//! the server falls back to CPU-exact distances).
//!
//! To run against real PJRT, replace the `use crate::runtime::xla_stub as
//! xla;` imports in `engine.rs` with the real `xla` crate and add it to
//! `Cargo.toml`.

#![allow(clippy::unnecessary_wraps)]

/// Stub error: carries a static reason; `Debug` matches how the engine
/// formats xla errors (`{e:?}`).
pub struct XlaError(pub &'static str);

impl std::fmt::Debug for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

const UNAVAILABLE: &str = "xla_extension unavailable (stub build; see runtime::xla_stub)";

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(XlaError(UNAVAILABLE))
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError(UNAVAILABLE))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError(UNAVAILABLE))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError(UNAVAILABLE))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(XlaError(UNAVAILABLE))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_v: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Err(XlaError(UNAVAILABLE))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(XlaError(UNAVAILABLE))
    }

    pub fn to_tuple1(&self) -> Result<Literal, XlaError> {
        Err(XlaError(UNAVAILABLE))
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal), XlaError> {
        Err(XlaError(UNAVAILABLE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err:?}").contains("unavailable"));
    }
}
