//! RerankService: a dedicated executor thread that owns the PJRT client +
//! compiled executable (the `xla` crate's handles are `Rc`-based and not
//! Send/Sync), serving re-rank calls to the router's worker pool over
//! channels. This mirrors how real serving stacks pin an accelerator
//! runtime to an executor thread.

use std::sync::{mpsc, Arc, Mutex};

use crate::core::error::{anyhow, Result};
use crate::core::matrix::Matrix;
use crate::runtime::engine::Engine;

struct Call {
    query: Vec<f32>,
    cand_ids: Vec<u32>,
    k: usize,
    resp: mpsc::Sender<Result<Vec<(f32, u32)>, String>>,
}

/// Handle to the executor thread. Clone-able across workers.
pub struct RerankService {
    tx: Mutex<mpsc::Sender<Call>>,
    handle: Option<std::thread::JoinHandle<()>>,
    pub max_cands: usize,
    pub dim: usize,
}

impl RerankService {
    /// Spawn the executor thread: it creates the PJRT client, compiles the
    /// rerank artifact for `dim`, then serves calls until dropped.
    pub fn start(artifacts_dir: std::path::PathBuf, dim: usize, data: Arc<Matrix>) -> Result<RerankService> {
        let (tx, rx) = mpsc::channel::<Call>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<usize, String>>();
        let handle = std::thread::Builder::new()
            .name("finger-pjrt".into())
            .spawn(move || {
                let exe = match Engine::new(&artifacts_dir)
                    .and_then(|e| e.compile_rerank_for_dim(dim))
                {
                    Ok(exe) => {
                        let cands = exe.spec.meta.get("cands").copied().unwrap_or(0);
                        let _ = ready_tx.send(Ok(cands));
                        exe
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                while let Ok(call) = rx.recv() {
                    let queries = Matrix::from_rows(&[call.query.clone()]);
                    let out = exe
                        .rerank(&data, &queries, &call.cand_ids)
                        .map(|r| {
                            let mut row = r.hits.into_iter().next().unwrap_or_default();
                            row.truncate(call.k);
                            row
                        })
                        .map_err(|e| format!("{e:#}"));
                    let _ = call.resp.send(out);
                }
            })
            .map_err(|e| anyhow!("spawn: {e}"))?;
        let max_cands = ready_rx
            .recv()
            .map_err(|_| anyhow!("pjrt thread died during init"))?
            .map_err(|e| anyhow!("{e}"))?;
        Ok(RerankService {
            tx: Mutex::new(tx),
            handle: Some(handle),
            max_cands,
            dim,
        })
    }

    /// Blocking re-rank of `cand_ids` (truncated to the artifact's panel
    /// width) against `query`; returns top-k (dist, id) ascending.
    pub fn rerank(&self, query: &[f32], cand_ids: &[u32], k: usize) -> Result<Vec<(f32, u32)>> {
        let (resp_tx, resp_rx) = mpsc::channel();
        let ids: Vec<u32> = cand_ids.iter().copied().take(self.max_cands).collect();
        {
            let tx = self.tx.lock().unwrap();
            tx.send(Call {
                query: query.to_vec(),
                cand_ids: ids,
                k,
                resp: resp_tx,
            })
            .map_err(|_| anyhow!("pjrt thread gone"))?;
        }
        resp_rx
            .recv()
            .map_err(|_| anyhow!("pjrt thread gone"))?
            .map_err(|e| anyhow!("{e}"))
    }
}

impl Drop for RerankService {
    fn drop(&mut self) {
        // Closing the channel stops the executor thread.
        {
            let (dummy_tx, _dummy_rx) = mpsc::channel();
            let mut guard = self.tx.lock().unwrap();
            *guard = dummy_tx;
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::l2_sq;
    use crate::core::rng::Pcg32;
    use crate::runtime::default_artifacts_dir;

    #[test]
    fn service_reranks_from_many_threads() {
        if !default_artifacts_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut rng = Pcg32::new(3);
        let mut data = Matrix::zeros(0, 0);
        for _ in 0..128 {
            let row: Vec<f32> = (0..32).map(|_| rng.next_gaussian()).collect();
            data.push_row(&row);
        }
        let data = Arc::new(data);
        let svc = Arc::new(
            RerankService::start(default_artifacts_dir(), 32, Arc::clone(&data)).unwrap(),
        );
        assert_eq!(svc.max_cands, 64);

        let mut handles = Vec::new();
        for t in 0..4 {
            let svc = Arc::clone(&svc);
            let data = Arc::clone(&data);
            handles.push(std::thread::spawn(move || {
                let mut rng = Pcg32::new(100 + t);
                for _ in 0..10 {
                    let q: Vec<f32> = (0..32).map(|_| rng.next_gaussian()).collect();
                    let ids: Vec<u32> = (0..50).collect();
                    let hits = svc.rerank(&q, &ids, 5).unwrap();
                    assert_eq!(hits.len(), 5);
                    // Spot-check first hit distance.
                    let want = l2_sq(&q, data.row(hits[0].1 as usize));
                    assert!((hits[0].0 - want).abs() < 1e-2 * (1.0 + want));
                    // Ascending.
                    for w in hits.windows(2) {
                        assert!(w[0].0 <= w[1].0);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn service_errors_without_artifacts() {
        let bogus = std::path::PathBuf::from("/nonexistent/artifacts");
        let data = Arc::new(Matrix::zeros(1, 4));
        assert!(RerankService::start(bogus, 4, data).is_err());
    }
}
