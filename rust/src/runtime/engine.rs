//! PJRT execution engine: loads `artifacts/*.hlo.txt` (AOT-lowered JAX +
//! Pallas, see `python/compile/aot.py`), compiles them once on the CPU
//! PJRT client, and serves batched executions from the Rust hot path.
//!
//! Interchange is HLO *text* — jax >= 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids.
//!
//! In offline builds the `xla` crate is replaced by
//! [`crate::runtime::xla_stub`]; every PJRT entry point then errors and
//! callers fall back to the CPU-exact path (see the stub's docs).
//!
//! Inputs are padded to each artifact's static shapes: queries replicate
//! row 0 semantics are avoided by masking on the caller side; candidate
//! slots are padded with `PAD_SQNORM` so they sort last in top-k.

use std::path::Path;

use crate::core::error::{anyhow, ensure, Result};
use crate::core::matrix::Matrix;
use crate::runtime::xla_stub as xla;
use crate::runtime::manifest::{ArtifactSpec, Manifest};

/// Squared-norm value for padded candidate slots — large enough to lose
/// every comparison, small enough to stay finite through f32 arithmetic.
pub const PAD_SQNORM: f32 = 1e30;

/// A compiled artifact ready to execute.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The engine: one PJRT client + all compiled artifacts.
pub struct Engine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
}

impl Engine {
    /// Create a CPU PJRT client and load the manifest (does not compile
    /// anything yet — call `compile` per artifact).
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Engine { client, manifest })
    }

    /// Compile one artifact by name.
    pub fn compile(&self, name: &str) -> Result<Executable> {
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("no artifact named {name}"))?
            .clone();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        Ok(Executable { spec, exe })
    }

    /// Compile the rerank artifact matching a data dimension.
    pub fn compile_rerank_for_dim(&self, dim: usize) -> Result<Executable> {
        let name = self
            .manifest
            .rerank_for_dim(dim)
            .ok_or_else(|| anyhow!("no rerank artifact for dim {dim}"))?
            .name
            .clone();
        self.compile(&name)
    }
}

/// Result of a rerank execution: global ids + squared distances per query.
#[derive(Clone, Debug, Default)]
pub struct RerankResult {
    /// Per query row: (distance, candidate id) ascending.
    pub hits: Vec<Vec<(f32, u32)>>,
}

impl Executable {
    /// Raw execute with literals.
    fn run(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.spec.name))?;
        bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))
    }

    /// Exact top-k re-rank via the `rerank` artifact. `queries` is B' × m
    /// (B' <= artifact batch), `cand_ids` the global ids of candidate rows
    /// in `data`. Inputs are padded to the artifact's static shapes.
    pub fn rerank(
        &self,
        data: &Matrix,
        queries: &Matrix,
        cand_ids: &[u32],
    ) -> Result<RerankResult> {
        ensure!(self.spec.kind == "rerank", "not a rerank artifact");
        let b = self.spec.meta["batch"];
        let c = self.spec.meta["cands"];
        let m = self.spec.meta["dim"];
        let k = self.spec.meta["k"];
        ensure!(queries.cols() == m, "query dim {} != {}", queries.cols(), m);
        ensure!(queries.rows() <= b, "batch overflow");
        ensure!(cand_ids.len() <= c, "candidate overflow");

        // Pad queries to (b, m) by repeating the last row (results sliced).
        let mut qbuf = vec![0.0f32; b * m];
        for i in 0..b {
            let src = queries.row(i.min(queries.rows().saturating_sub(1)));
            qbuf[i * m..(i + 1) * m].copy_from_slice(src);
        }
        // Gather + pad candidates; padded slots get PAD_SQNORM.
        let mut cbuf = vec![0.0f32; c * m];
        let mut sq = vec![PAD_SQNORM; c];
        for (j, &id) in cand_ids.iter().enumerate() {
            let row = data.row(id as usize);
            cbuf[j * m..(j + 1) * m].copy_from_slice(row);
            sq[j] = crate::core::distance::norm_sq(row);
        }

        let ql = xla::Literal::vec1(&qbuf)
            .reshape(&[b as i64, m as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let cl = xla::Literal::vec1(&cbuf)
            .reshape(&[c as i64, m as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let sl = xla::Literal::vec1(&sq);

        let out = self.run(&[ql, cl, sl])?;
        let (dist_l, idx_l) = out.to_tuple2().map_err(|e| anyhow!("{e:?}"))?;
        let dists: Vec<f32> = dist_l.to_vec().map_err(|e| anyhow!("{e:?}"))?;
        let idxs: Vec<i32> = idx_l.to_vec().map_err(|e| anyhow!("{e:?}"))?;

        let mut hits = Vec::with_capacity(queries.rows());
        for qi in 0..queries.rows() {
            let mut row = Vec::with_capacity(k);
            for j in 0..k {
                let pos = idxs[qi * k + j];
                if pos < 0 || pos as usize >= cand_ids.len() {
                    continue; // padded slot leaked into top-k (fewer cands than k)
                }
                row.push((dists[qi * k + j], cand_ids[pos as usize]));
            }
            hits.push(row);
        }
        Ok(RerankResult { hits })
    }

    /// Batched squared-L2 scoring via a `score_l2` artifact: returns the
    /// (queries x cand_ids) panel, unpadded.
    pub fn score_l2(
        &self,
        data: &Matrix,
        queries: &Matrix,
        cand_ids: &[u32],
    ) -> Result<Vec<Vec<f32>>> {
        ensure!(self.spec.kind == "score_l2", "not a score artifact");
        let b = self.spec.meta["batch"];
        let c = self.spec.meta["cands"];
        let m = self.spec.meta["dim"];
        ensure!(queries.cols() == m && queries.rows() <= b && cand_ids.len() <= c);

        let mut qbuf = vec![0.0f32; b * m];
        for i in 0..b {
            let src = queries.row(i.min(queries.rows().saturating_sub(1)));
            qbuf[i * m..(i + 1) * m].copy_from_slice(src);
        }
        let mut cbuf = vec![0.0f32; c * m];
        let mut sq = vec![0.0f32; c];
        for (j, &id) in cand_ids.iter().enumerate() {
            let row = data.row(id as usize);
            cbuf[j * m..(j + 1) * m].copy_from_slice(row);
            sq[j] = crate::core::distance::norm_sq(row);
        }
        let ql = xla::Literal::vec1(&qbuf)
            .reshape(&[b as i64, m as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let cl = xla::Literal::vec1(&cbuf)
            .reshape(&[c as i64, m as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let sl = xla::Literal::vec1(&sq);
        let out = self.run(&[ql, cl, sl])?;
        let panel = out.to_tuple1().map_err(|e| anyhow!("{e:?}"))?;
        let flat: Vec<f32> = panel.to_vec().map_err(|e| anyhow!("{e:?}"))?;
        let mut rows = Vec::with_capacity(queries.rows());
        for qi in 0..queries.rows() {
            rows.push(flat[qi * c..qi * c + cand_ids.len()].to_vec());
        }
        Ok(rows)
    }
}

/// Locate the artifacts directory: $FINGER_ARTIFACTS or ./artifacts.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("FINGER_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::l2_sq;
    use crate::core::rng::Pcg32;

    fn artifacts_available() -> bool {
        default_artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn rerank_matches_cpu_exact() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let engine = Engine::new(&default_artifacts_dir()).unwrap();
        let exe = engine.compile("rerank_b4_c64_d32_k5").unwrap();

        let mut rng = Pcg32::new(11);
        let mut data = Matrix::zeros(0, 0);
        for _ in 0..100 {
            let row: Vec<f32> = (0..32).map(|_| rng.next_gaussian()).collect();
            data.push_row(&row);
        }
        let mut queries = Matrix::zeros(0, 0);
        for _ in 0..3 {
            let row: Vec<f32> = (0..32).map(|_| rng.next_gaussian()).collect();
            queries.push_row(&row);
        }
        let cand_ids: Vec<u32> = (0..60).collect();
        let res = exe.rerank(&data, &queries, &cand_ids).unwrap();
        assert_eq!(res.hits.len(), 3);
        for qi in 0..3 {
            // CPU-exact top-5 among the candidate set.
            let q = queries.row(qi);
            let mut exact: Vec<(f32, u32)> = cand_ids
                .iter()
                .map(|&id| (l2_sq(q, data.row(id as usize)), id))
                .collect();
            exact.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let want: Vec<u32> = exact[..5].iter().map(|x| x.1).collect();
            let got: Vec<u32> = res.hits[qi].iter().map(|x| x.1).collect();
            assert_eq!(got, want, "query {qi}");
            for (j, &(d, id)) in res.hits[qi].iter().enumerate() {
                let true_d = l2_sq(q, data.row(id as usize));
                assert!((d - true_d).abs() < 1e-2 * (1.0 + true_d), "dist {j}");
            }
        }
    }

    #[test]
    fn rerank_with_fewer_candidates_than_panel() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = Engine::new(&default_artifacts_dir()).unwrap();
        let exe = engine.compile("rerank_b4_c64_d32_k5").unwrap();
        let mut rng = Pcg32::new(12);
        let mut data = Matrix::zeros(0, 0);
        for _ in 0..10 {
            let row: Vec<f32> = (0..32).map(|_| rng.next_gaussian()).collect();
            data.push_row(&row);
        }
        let queries = Matrix::from_rows(&[data.row(0).to_vec()]);
        let cand_ids: Vec<u32> = (0..10).collect();
        let res = exe.rerank(&data, &queries, &cand_ids).unwrap();
        // Self-match must rank first with ~zero distance.
        assert_eq!(res.hits[0][0].1, 0);
        assert!(res.hits[0][0].0 < 1e-3);
        // Padded slots must never appear.
        assert!(res.hits[0].iter().all(|&(_, id)| id < 10));
    }

    #[test]
    fn score_l2_panel_matches_cpu() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = Engine::new(&default_artifacts_dir()).unwrap();
        let exe = engine.compile("score_l2_b8_c256_d128").unwrap();
        let mut rng = Pcg32::new(13);
        let mut data = Matrix::zeros(0, 0);
        for _ in 0..300 {
            let row: Vec<f32> = (0..128).map(|_| rng.next_gaussian()).collect();
            data.push_row(&row);
        }
        let mut queries = Matrix::zeros(0, 0);
        for _ in 0..5 {
            let row: Vec<f32> = (0..128).map(|_| rng.next_gaussian()).collect();
            queries.push_row(&row);
        }
        let cand_ids: Vec<u32> = (0..200).collect();
        let rows = exe.score_l2(&data, &queries, &cand_ids).unwrap();
        assert_eq!(rows.len(), 5);
        assert_eq!(rows[0].len(), 200);
        for qi in 0..5 {
            for (j, &id) in cand_ids.iter().enumerate().step_by(37) {
                let want = l2_sq(queries.row(qi), data.row(id as usize));
                let got = rows[qi][j];
                assert!((got - want).abs() < 1e-2 * (1.0 + want), "({qi},{j}): {got} vs {want}");
            }
        }
    }

    #[test]
    fn missing_artifact_name_errors() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = Engine::new(&default_artifacts_dir()).unwrap();
        assert!(engine.compile("nonexistent").is_err());
    }
}
