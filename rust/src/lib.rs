//! # finger-ann
//!
//! A from-scratch reproduction of **FINGER: Fast Inference for Graph-based
//! Approximate Nearest Neighbor Search** (Chen et al., WWW 2023) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! * [`core`] — distances, RNG, dense linear algebra, stats, JSON, errors.
//! * [`data`] — synthetic benchmark datasets, fvecs/ivecs IO, ground truth,
//!   tagged index persistence.
//! * [`graph`] — HNSW / Vamana / NN-descent substrates + Algorithm 1 search.
//! * [`finger`] — the paper's contribution: Algorithms 2–4 and RPLSH.
//! * [`quant`] — IVF-PQ quantization baselines (Figure 7).
//! * [`index`] — the unified [`index::AnnIndex`] trait + pooled
//!   [`index::SearchContext`]: one search API across all families.
//! * [`runtime`] — PJRT execution of AOT-compiled JAX/Pallas artifacts
//!   (stubbed offline; see `runtime::xla_stub`).
//! * [`router`] — serving layer: dynamic batching, workers, metrics, any
//!   `AnnIndex` behind the server.
//! * [`wal`] — durable mutation plane: checksummed write-ahead log, group
//!   commit, snapshot checkpoints, crash recovery.
//! * [`repl`] — primary/backup replication: WAL streaming over TCP,
//!   configurable ack levels, snapshot catch-up, fingerprint divergence
//!   checks.
//! * [`eval`] — recall/throughput harnesses regenerating every figure.
//!
//! See the repository `README.md` for the paper-to-module map and the
//! `AnnIndex` API tour.

pub mod cli;
pub mod core;
pub mod data;
pub mod eval;
pub mod finger;
pub mod graph;
pub mod index;
pub mod quant;
pub mod repl;
pub mod router;
pub mod runtime;
pub mod testutil;
pub mod wal;
