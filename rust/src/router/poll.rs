//! Zero-dependency epoll: a thin poller over raw Linux syscalls.
//!
//! The offline build has no `libc` (or any other crate), so the event
//! loop talks to the kernel directly: `epoll_create1` / `epoll_ctl` /
//! `epoll_pwait` / `eventfd2` are issued via inline-asm syscall stubs on
//! x86_64 and aarch64, wrapped in the tiny safe [`Poller`] / [`Waker`]
//! API the serving plane consumes. Everything is level-triggered — the
//! connection state machine in `router::conn` re-reads/re-writes until
//! `WouldBlock`, so level semantics are the simple and correct choice.
//!
//! On non-Linux targets (or exotic architectures) the same API exists
//! but every constructor returns `Unsupported`; `SUPPORTED` is the
//! compile-time switch the server uses to fall back to
//! thread-per-connection.

#![allow(dead_code)]

use std::io;

/// True when the real epoll backend is compiled in.
pub const SUPPORTED: bool = cfg!(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
));

/// One readiness notification, decoded from the kernel's epoll_event.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The token registered with [`Poller::add`] (connection slot, or one
    /// of the server's sentinel tokens for the listener and waker).
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// EPOLLERR / EPOLLHUP: the peer is gone or the socket errored.
    pub errhup: bool,
}

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod sys {
    use super::Event;
    use std::io;
    use std::os::fd::{AsRawFd, FromRawFd, OwnedFd, RawFd};

    // -- raw syscall stubs ------------------------------------------------

    #[cfg(target_arch = "x86_64")]
    mod nr {
        pub const READ: usize = 0;
        pub const WRITE: usize = 1;
        pub const EPOLL_CTL: usize = 233;
        pub const EPOLL_PWAIT: usize = 281;
        pub const EVENTFD2: usize = 290;
        pub const EPOLL_CREATE1: usize = 291;
        pub const PRLIMIT64: usize = 302;
    }

    #[cfg(target_arch = "aarch64")]
    mod nr {
        pub const READ: usize = 63;
        pub const WRITE: usize = 64;
        pub const EPOLL_CTL: usize = 21;
        pub const EPOLL_PWAIT: usize = 22;
        pub const EVENTFD2: usize = 19;
        pub const EPOLL_CREATE1: usize = 20;
        pub const PRLIMIT64: usize = 261;
    }

    #[cfg(target_arch = "x86_64")]
    unsafe fn syscall6(
        n: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
        a6: usize,
    ) -> isize {
        let ret: isize;
        core::arch::asm!(
            "syscall",
            inlateout("rax") n as isize => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            in("r10") a4,
            in("r8") a5,
            in("r9") a6,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }

    #[cfg(target_arch = "aarch64")]
    unsafe fn syscall6(
        n: usize,
        a1: usize,
        a2: usize,
        a3: usize,
        a4: usize,
        a5: usize,
        a6: usize,
    ) -> isize {
        let ret: isize;
        core::arch::asm!(
            "svc 0",
            in("x8") n,
            inlateout("x0") a1 => ret,
            in("x1") a2,
            in("x2") a3,
            in("x3") a4,
            in("x4") a5,
            in("x5") a6,
            options(nostack),
        );
        ret
    }

    /// Fold the kernel's negative-errno convention into io::Result.
    fn check(ret: isize) -> io::Result<usize> {
        if (-4095..0).contains(&ret) {
            Err(io::Error::from_raw_os_error(-ret as i32))
        } else {
            Ok(ret as usize)
        }
    }

    // -- epoll constants (uapi/linux/eventpoll.h) -------------------------

    const EPOLL_CLOEXEC: usize = 0o2000000;
    const EPOLL_CTL_ADD: usize = 1;
    const EPOLL_CTL_DEL: usize = 2;
    const EPOLL_CTL_MOD: usize = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;

    const EFD_NONBLOCK: usize = 0o4000;
    const EFD_CLOEXEC: usize = 0o2000000;

    /// The kernel's epoll_event: packed on x86_64, naturally aligned on
    /// every other architecture (uapi `EPOLL_PACKED`).
    #[cfg(target_arch = "x86_64")]
    #[repr(C, packed)]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    #[cfg(not(target_arch = "x86_64"))]
    #[repr(C)]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    fn interest_mask(readable: bool, writable: bool) -> u32 {
        let mut m = 0;
        if readable {
            m |= EPOLLIN;
        }
        if writable {
            m |= EPOLLOUT;
        }
        m
    }

    /// Level-triggered epoll instance.
    pub struct Poller {
        ep: OwnedFd,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let fd = check(unsafe { syscall6(nr::EPOLL_CREATE1, EPOLL_CLOEXEC, 0, 0, 0, 0, 0) })?;
            Ok(Poller { ep: unsafe { OwnedFd::from_raw_fd(fd as RawFd) } })
        }

        fn ctl(&self, op: usize, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
            let ev = EpollEvent { events, data: token };
            let ptr = if op == EPOLL_CTL_DEL { 0 } else { &ev as *const EpollEvent as usize };
            check(unsafe {
                syscall6(nr::EPOLL_CTL, self.ep.as_raw_fd() as usize, op, fd as usize, ptr, 0, 0)
            })
            .map(|_| ())
        }

        pub fn add(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, interest_mask(readable, writable), token)
        }

        pub fn modify(
            &self,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, interest_mask(readable, writable), token)
        }

        pub fn remove(&self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
        }

        /// Wait up to `timeout_ms` (-1 = forever) and decode readiness into
        /// `out` (cleared first). EINTR is not an error — it returns an
        /// empty set so the caller's loop re-checks its stop flag.
        pub fn wait(&self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
            out.clear();
            const MAX_EVENTS: usize = 1024;
            let mut buf = [EpollEvent { events: 0, data: 0 }; MAX_EVENTS];
            let ret = unsafe {
                syscall6(
                    nr::EPOLL_PWAIT,
                    self.ep.as_raw_fd() as usize,
                    buf.as_mut_ptr() as usize,
                    MAX_EVENTS,
                    timeout_ms as usize,
                    0, // sigmask: NULL (no signal atomicity needed)
                    8, // sigsetsize (ignored with a NULL mask)
                )
            };
            let n = match check(ret) {
                Ok(n) => n,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => 0,
                Err(e) => return Err(e),
            };
            for ev in buf.iter().take(n) {
                let bits = ev.events;
                out.push(Event {
                    token: ev.data,
                    readable: bits & EPOLLIN != 0,
                    writable: bits & EPOLLOUT != 0,
                    errhup: bits & (EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(())
        }
    }

    /// Cross-thread wakeup for the event loop: an eventfd registered in
    /// the poller. Worker threads `wake()` after queuing a completion;
    /// the loop `drain()`s on readiness. Writes coalesce in the kernel's
    /// 64-bit counter, so a storm of wakes costs one loop iteration.
    pub struct Waker {
        efd: OwnedFd,
    }

    impl Waker {
        pub fn new() -> io::Result<Waker> {
            let flags = EFD_NONBLOCK | EFD_CLOEXEC;
            let fd = check(unsafe { syscall6(nr::EVENTFD2, 0, flags, 0, 0, 0, 0) })?;
            Ok(Waker { efd: unsafe { OwnedFd::from_raw_fd(fd as RawFd) } })
        }

        pub fn raw_fd(&self) -> RawFd {
            self.efd.as_raw_fd()
        }

        pub fn wake(&self) {
            let one = 1u64.to_ne_bytes();
            // EAGAIN (counter saturated) still leaves the fd readable, so
            // the wakeup is delivered either way; nothing to handle.
            let _ = unsafe {
                syscall6(
                    nr::WRITE,
                    self.efd.as_raw_fd() as usize,
                    one.as_ptr() as usize,
                    one.len(),
                    0,
                    0,
                    0,
                )
            };
        }

        pub fn drain(&self) {
            let mut buf = [0u8; 8];
            let _ = unsafe {
                syscall6(
                    nr::READ,
                    self.efd.as_raw_fd() as usize,
                    buf.as_mut_ptr() as usize,
                    buf.len(),
                    0,
                    0,
                    0,
                )
            };
        }
    }

    // -- fd-limit helper (used by the connection-soak tests) --------------

    const RLIMIT_NOFILE: usize = 7;

    #[repr(C)]
    struct Rlimit64 {
        cur: u64,
        max: u64,
    }

    /// Raise RLIMIT_NOFILE's soft limit to the hard limit and return the
    /// new soft limit. Lets the 2k-connection soak run under the stingy
    /// default soft limit most CI containers ship with.
    pub fn raise_nofile_limit() -> io::Result<u64> {
        let mut cur = Rlimit64 { cur: 0, max: 0 };
        check(unsafe {
            syscall6(nr::PRLIMIT64, 0, RLIMIT_NOFILE, 0, &mut cur as *mut Rlimit64 as usize, 0, 0)
        })?;
        if cur.cur >= cur.max {
            return Ok(cur.cur);
        }
        let want = Rlimit64 { cur: cur.max, max: cur.max };
        check(unsafe {
            syscall6(nr::PRLIMIT64, 0, RLIMIT_NOFILE, &want as *const Rlimit64 as usize, 0, 0, 0)
        })?;
        Ok(want.cur)
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod sys {
    //! Stub backend: same API, every constructor reports Unsupported. The
    //! server checks [`super::SUPPORTED`] and falls back to
    //! thread-per-connection before ever calling these.

    use super::Event;
    use std::io;
    use std::os::fd::RawFd;

    pub struct Poller;

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Err(io::Error::new(io::ErrorKind::Unsupported, "epoll unavailable on this target"))
        }

        pub fn add(&self, _fd: RawFd, _token: u64, _r: bool, _w: bool) -> io::Result<()> {
            unreachable!("stub poller cannot be constructed")
        }

        pub fn modify(&self, _fd: RawFd, _token: u64, _r: bool, _w: bool) -> io::Result<()> {
            unreachable!("stub poller cannot be constructed")
        }

        pub fn remove(&self, _fd: RawFd) -> io::Result<()> {
            unreachable!("stub poller cannot be constructed")
        }

        pub fn wait(&self, _out: &mut Vec<Event>, _timeout_ms: i32) -> io::Result<()> {
            unreachable!("stub poller cannot be constructed")
        }
    }

    pub struct Waker;

    impl Waker {
        pub fn new() -> io::Result<Waker> {
            Err(io::Error::new(io::ErrorKind::Unsupported, "eventfd unavailable on this target"))
        }

        pub fn raw_fd(&self) -> RawFd {
            unreachable!("stub waker cannot be constructed")
        }

        pub fn wake(&self) {}

        pub fn drain(&self) {}
    }

    pub fn raise_nofile_limit() -> io::Result<u64> {
        Err(io::Error::new(io::ErrorKind::Unsupported, "prlimit unavailable on this target"))
    }
}

pub use sys::{raise_nofile_limit, Poller, Waker};

#[cfg(all(test, target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;

    #[test]
    fn waker_wakes_and_drains() {
        let poller = Poller::new().unwrap();
        let waker = Waker::new().unwrap();
        poller.add(waker.raw_fd(), 7, true, false).unwrap();

        let mut events = Vec::new();
        poller.wait(&mut events, 0).unwrap();
        assert!(events.is_empty(), "nothing ready before wake");

        waker.wake();
        waker.wake(); // coalesces
        poller.wait(&mut events, 1000).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);

        waker.drain();
        poller.wait(&mut events, 0).unwrap();
        assert!(events.is_empty(), "drained waker is quiet again");
    }

    #[test]
    fn socket_readability_and_interest_changes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let mut client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let poller = Poller::new().unwrap();
        poller.add(server.as_raw_fd(), 42, true, false).unwrap();

        let mut events = Vec::new();
        poller.wait(&mut events, 0).unwrap();
        assert!(events.is_empty());

        client.write_all(b"ping").unwrap();
        poller.wait(&mut events, 1000).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 42);
        assert!(events[0].readable && !events[0].errhup);

        // A connected socket's send buffer is writable; after MOD to
        // write-interest the same fd reports EPOLLOUT.
        poller.modify(server.as_raw_fd(), 42, false, true).unwrap();
        poller.wait(&mut events, 1000).unwrap();
        assert_eq!(events.len(), 1);
        assert!(events[0].writable);

        // Peer close with zero interests still surfaces as err/hup.
        poller.modify(server.as_raw_fd(), 42, false, false).unwrap();
        drop(client);
        poller.wait(&mut events, 1000).unwrap();
        assert_eq!(events.len(), 1);
        assert!(events[0].errhup, "peer close reported: {:?}", events[0]);

        poller.remove(server.as_raw_fd()).unwrap();
        poller.wait(&mut events, 0).unwrap();
        assert!(events.is_empty());
    }

    #[test]
    fn raise_nofile_reports_a_sane_limit() {
        let limit = raise_nofile_limit().unwrap();
        assert!(limit >= 256, "soft fd limit after raise: {limit}");
        // Idempotent.
        assert_eq!(raise_nofile_limit().unwrap(), limit);
    }
}
