//! The serving layer (L3 coordination): JSON-line protocol, dynamic
//! batcher with backpressure, worker pool over any `AnnIndex`, metrics.

pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod server;

pub use batcher::{Batcher, SubmitError};
pub use metrics::Metrics;
pub use protocol::{MutOutcome, MutResponse, QueryRequest, QueryResponse, Request};
pub use server::{Client, ServeIndex, Server, ServerConfig};
