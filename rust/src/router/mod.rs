//! The serving layer (L3 coordination): JSON-line protocol, zero-dep
//! epoll event loop (with a thread-per-connection fallback), dynamic
//! batcher with backpressure, worker pool over any `AnnIndex`, metrics.

pub mod batcher;
pub mod conn;
pub mod metrics;
pub mod poll;
pub mod protocol;
pub mod server;

pub use batcher::{Batcher, SubmitError};
pub use metrics::Metrics;
pub use protocol::{MutOutcome, MutResponse, QueryRequest, QueryResponse, Request};
pub use server::{Client, ServeIndex, ServeMode, Server, ServerConfig};
