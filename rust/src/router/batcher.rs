//! Dynamic batcher: requests accumulate in a bounded queue; a batch is
//! released when it reaches `max_batch` or the oldest request has waited
//! `max_wait`. Backpressure = bounded queue, reject on overflow (the
//! caller surfaces the rejection to the client).
//!
//! Invariants (proptested in rust/tests/router_props.rs):
//!  * every submitted request appears in exactly one batch;
//!  * batch size never exceeds `max_batch`;
//!  * within a batch, requests preserve FIFO submission order.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// A queued unit of work.
pub struct Pending<T> {
    pub item: T,
    pub enqueued_at: Instant,
}

struct State<T> {
    queue: VecDeque<Pending<T>>,
    closed: bool,
}

pub struct Batcher<T> {
    state: Mutex<State<T>>,
    cv: Condvar,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub max_queue: usize,
}

/// Submission error: queue full or batcher closed.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    Full,
    Closed,
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize, max_wait: Duration, max_queue: usize) -> Batcher<T> {
        assert!(max_batch > 0 && max_queue >= max_batch);
        Batcher {
            state: Mutex::new(State {
                queue: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
            max_batch,
            max_wait,
            max_queue,
        }
    }

    /// Enqueue one request.
    pub fn submit(&self, item: T) -> Result<(), SubmitError> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(SubmitError::Closed);
        }
        if st.queue.len() >= self.max_queue {
            return Err(SubmitError::Full);
        }
        st.queue.push_back(Pending {
            item,
            enqueued_at: Instant::now(),
        });
        self.cv.notify_all();
        Ok(())
    }

    /// Block until a batch is ready (full, or oldest item timed out, or
    /// closed-and-draining). Returns None only when closed and empty.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.queue.is_empty() {
                let oldest = st.queue.front().unwrap().enqueued_at;
                let waited = oldest.elapsed();
                if st.queue.len() >= self.max_batch || waited >= self.max_wait || st.closed {
                    let n = st.queue.len().min(self.max_batch);
                    let batch: Vec<T> = st.queue.drain(..n).map(|p| p.item).collect();
                    return Some(batch);
                }
                // Wait out the remaining window (or a new arrival).
                let remaining = self.max_wait - waited;
                let (guard, _) = self.cv.wait_timeout(st, remaining).unwrap();
                st = guard;
            } else if st.closed {
                return None;
            } else {
                let (guard, _) = self.cv.wait_timeout(st, self.max_wait).unwrap();
                st = guard;
            }
        }
    }

    /// Close: pending items still drain via `next_batch`.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.cv.notify_all();
    }

    pub fn queue_len(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn batches_at_max_batch() {
        let b = Batcher::new(4, Duration::from_secs(10), 64);
        for i in 0..4 {
            b.submit(i).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![0, 1, 2, 3]);
    }

    #[test]
    fn times_out_partial_batch() {
        let b = Batcher::new(100, Duration::from_millis(20), 1000);
        b.submit(7).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![7]);
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn rejects_on_overflow() {
        let b = Batcher::new(2, Duration::from_secs(1), 2);
        b.submit(1).unwrap();
        b.submit(2).unwrap();
        assert_eq!(b.submit(3), Err(SubmitError::Full));
    }

    #[test]
    fn close_drains_then_none() {
        let b = Batcher::new(10, Duration::from_secs(10), 100);
        b.submit(1).unwrap();
        b.submit(2).unwrap();
        b.close();
        assert_eq!(b.next_batch().unwrap(), vec![1, 2]);
        assert!(b.next_batch().is_none());
        assert_eq!(b.submit(3), Err(SubmitError::Closed));
    }

    #[test]
    fn concurrent_producers_all_delivered() {
        let b = Arc::new(Batcher::new(8, Duration::from_millis(5), 10_000));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let b = Arc::clone(&b);
            handles.push(std::thread::spawn(move || {
                for i in 0..100u64 {
                    while b.submit(t * 1000 + i).is_err() {
                        std::thread::yield_now();
                    }
                }
            }));
        }
        let consumer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(batch) = b.next_batch() {
                    assert!(batch.len() <= 8);
                    seen.extend(batch);
                }
                seen
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        b.close();
        let mut seen = consumer.join().unwrap();
        seen.sort_unstable();
        assert_eq!(seen.len(), 400);
        seen.dedup();
        assert_eq!(seen.len(), 400, "every request delivered exactly once");
    }
}
