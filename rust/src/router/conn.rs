//! Per-connection state machine for the epoll serving plane.
//!
//! Each accepted socket gets a [`Conn`]: an incremental JSON-line framer
//! over a pooled read buffer, a sequence-numbered reorder stage so
//! pipelined requests answered out of order by the worker pool still go
//! back in request order, and a write buffer with explicit backpressure
//! (when a client stops reading its responses, we stop reading its
//! requests). The event loop in `router::server` owns a slab of these
//! and drives them from `epoll` readiness; nothing here blocks.

use std::collections::BTreeMap;
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::sync::Mutex;

/// Hard cap on a single request frame. A line that exceeds this without a
/// terminating `\n` is a protocol violation (or an attack); the server
/// answers with a structured error and closes the connection.
pub const MAX_FRAME: usize = 32 * 1024 * 1024;

/// Per-connection cap on requests handed to the workers but not yet
/// answered. Past this we stop reading from the socket — the kernel's
/// receive buffer (and eventually the client) absorbs the rest.
pub const MAX_INFLIGHT: usize = 256;

/// Pause reading when this many response bytes are queued unwritten; a
/// client that won't drain its responses doesn't get to buffer more work.
pub const WRITE_HIGH_WATER: usize = 256 * 1024;

const READ_CHUNK: usize = 16 * 1024;

/// Reusable byte buffers shared across connections. Short-lived
/// connections then cost no steady-state allocation: buffers are
/// recycled through here instead of freed. Oversized buffers (a client
/// that sent one huge frame) are dropped rather than pooled so a burst
/// can't pin memory forever.
pub struct BufPool {
    free: Mutex<Vec<Vec<u8>>>,
    max_pooled: usize,
}

/// Buffers larger than this are dropped on recycle instead of pooled.
const MAX_POOLED_BUF: usize = 1024 * 1024;

impl BufPool {
    pub fn new(max_pooled: usize) -> BufPool {
        BufPool { free: Mutex::new(Vec::new()), max_pooled }
    }

    pub fn get(&self) -> Vec<u8> {
        self.free
            .lock()
            .map(|mut f| f.pop())
            .unwrap_or(None)
            .unwrap_or_else(|| Vec::with_capacity(READ_CHUNK))
    }

    pub fn put(&self, mut buf: Vec<u8>) {
        buf.clear();
        if buf.capacity() > MAX_POOLED_BUF {
            return;
        }
        if let Ok(mut f) = self.free.lock() {
            if f.len() < self.max_pooled {
                f.push(buf);
            }
        }
    }

    /// Number of buffers currently pooled (for tests/metrics).
    pub fn pooled(&self) -> usize {
        self.free.lock().map(|f| f.len()).unwrap_or(0)
    }
}

/// What `read_frames` observed on the socket.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadStatus {
    /// Socket drained (WouldBlock) or reading paused by backpressure.
    Ok,
    /// Peer sent EOF; already-buffered frames were still extracted.
    Eof,
    /// A frame exceeded [`MAX_FRAME`] without a newline.
    FrameTooLong,
    /// Hard socket error; connection is dead.
    Err,
}

/// One accepted connection: framing in, ordered responses out.
pub struct Conn {
    pub stream: TcpStream,
    /// Generation of the slab slot holding this conn; completions carry
    /// it so answers for a previous occupant of the slot are discarded.
    pub gen: u64,
    rbuf: Vec<u8>,
    /// Scan resume offset into `rbuf`: bytes before this were already
    /// searched for `\n` in a previous pass.
    scan: usize,
    wbuf: Vec<u8>,
    wpos: usize,
    /// Sequence assigned to the next frame read from this connection.
    next_seq: u64,
    /// Sequence whose response is next in line to be written.
    next_write: u64,
    /// Completed responses waiting on earlier sequences (pipelining).
    pending: BTreeMap<u64, String>,
    /// Frames handed out but not yet completed.
    inflight: usize,
    eof: bool,
    dead: bool,
    /// Interest currently registered with the poller `(read, write)`,
    /// tracked so the loop only issues `epoll_ctl(MOD)` on change.
    pub interest: (bool, bool),
}

impl Conn {
    pub fn new(stream: TcpStream, gen: u64, pool: &BufPool) -> Conn {
        Conn {
            stream,
            gen,
            rbuf: pool.get(),
            scan: 0,
            wbuf: pool.get(),
            wpos: 0,
            next_seq: 0,
            next_write: 0,
            pending: BTreeMap::new(),
            inflight: 0,
            eof: false,
            dead: false,
            interest: (true, false),
        }
    }

    /// Whether the framer should keep consuming socket bytes.
    pub fn want_read(&self) -> bool {
        !self.eof
            && !self.dead
            && self.inflight < MAX_INFLIGHT
            && self.pending_write() < WRITE_HIGH_WATER
    }

    pub fn want_write(&self) -> bool {
        !self.dead && self.pending_write() > 0
    }

    fn pending_write(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    /// Read until WouldBlock/EOF (or until backpressure pauses us),
    /// appending every complete newline-terminated frame to `frames`
    /// tagged with its sequence number. Empty lines are ignored, like
    /// the blocking path always has.
    pub fn read_frames(&mut self, frames: &mut Vec<(u64, String)>) -> ReadStatus {
        if self.dead {
            return ReadStatus::Err;
        }
        loop {
            if !self.want_read() {
                return if self.eof { ReadStatus::Eof } else { ReadStatus::Ok };
            }
            let start = self.rbuf.len();
            self.rbuf.resize(start + READ_CHUNK, 0);
            let n = match self.stream.read(&mut self.rbuf[start..]) {
                Ok(n) => n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    self.rbuf.truncate(start);
                    return ReadStatus::Ok;
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {
                    self.rbuf.truncate(start);
                    continue;
                }
                Err(_) => {
                    self.rbuf.truncate(start);
                    self.dead = true;
                    return ReadStatus::Err;
                }
            };
            self.rbuf.truncate(start + n);
            if n == 0 {
                self.eof = true;
                self.extract_lines(frames);
                return ReadStatus::Eof;
            }
            self.extract_lines(frames);
            if self.rbuf.len() > MAX_FRAME {
                self.dead = true;
                return ReadStatus::FrameTooLong;
            }
        }
    }

    /// Pull every complete line out of `rbuf`, assign sequences, and
    /// compact the consumed prefix.
    fn extract_lines(&mut self, frames: &mut Vec<(u64, String)>) {
        let mut consumed = 0;
        while let Some(rel) = self.rbuf[self.scan..].iter().position(|&b| b == b'\n') {
            let end = self.scan + rel;
            let line = &self.rbuf[consumed..end];
            let text = String::from_utf8_lossy(line).trim().to_string();
            consumed = end + 1;
            self.scan = consumed;
            if text.is_empty() {
                continue;
            }
            let seq = self.next_seq;
            self.next_seq += 1;
            self.inflight += 1;
            frames.push((seq, text));
        }
        if consumed > 0 {
            self.rbuf.drain(..consumed);
            self.scan = self.rbuf.len();
        } else {
            self.scan = self.rbuf.len();
        }
    }

    /// Deliver the response for frame `seq`. Responses are buffered until
    /// every earlier sequence has been answered, then written in request
    /// order — pipelined clients see responses in the order they asked.
    pub fn complete(&mut self, seq: u64, line: &str) {
        if self.inflight > 0 {
            self.inflight -= 1;
        }
        self.pending.insert(seq, line.to_string());
        while let Some(ready) = self.pending.remove(&self.next_write) {
            self.wbuf.extend_from_slice(ready.as_bytes());
            self.wbuf.push(b'\n');
            self.next_write += 1;
        }
    }

    /// Queue a line out of band (parse errors, shutdown notices) — it
    /// still consumes the frame's slot in the response order when tagged
    /// via [`Conn::complete`]; this variant is for pre-framing failures
    /// (e.g. an overlong frame) where no sequence exists.
    pub fn push_raw(&mut self, line: &str) {
        self.wbuf.extend_from_slice(line.as_bytes());
        self.wbuf.push(b'\n');
    }

    /// Write as much of the buffered output as the socket accepts.
    /// Returns false if the connection died.
    pub fn flush(&mut self) -> bool {
        if self.dead {
            return false;
        }
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.dead = true;
                    return false;
                }
                Ok(n) => self.wpos += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    return false;
                }
            }
        }
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        } else if self.wpos > READ_CHUNK {
            // Compact occasionally so a slow reader doesn't grow the
            // buffer without bound on the consumed side.
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
        true
    }

    pub fn mark_dead(&mut self) {
        self.dead = true;
    }

    pub fn is_dead(&self) -> bool {
        self.dead
    }

    /// True once the conversation is over: peer sent EOF, every accepted
    /// frame has been answered, and all bytes are on the wire.
    pub fn finished(&self) -> bool {
        self.dead
            || (self.eof
                && self.inflight == 0
                && self.pending.is_empty()
                && self.pending_write() == 0)
    }

    /// Return the buffers to the pool on close.
    pub fn recycle(self, pool: &BufPool) {
        pool.put(self.rbuf);
        pool.put(self.wbuf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    fn pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let c = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        let (s, _) = l.accept().unwrap();
        s.set_nonblocking(true).unwrap();
        (c, s)
    }

    #[test]
    fn frames_split_across_reads_reassemble() {
        let (mut client, server) = pair();
        let pool = BufPool::new(8);
        let mut conn = Conn::new(server, 0, &pool);
        let mut frames = Vec::new();

        // Trickle a frame one byte at a time.
        for b in b"{\"q\":1}" {
            client.write_all(&[*b]).unwrap();
            std::thread::sleep(std::time::Duration::from_millis(1));
            assert_eq!(conn.read_frames(&mut frames), ReadStatus::Ok);
            assert!(frames.is_empty(), "no frame before newline");
        }
        client.write_all(b"\n").unwrap();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert_eq!(conn.read_frames(&mut frames), ReadStatus::Ok);
        assert_eq!(frames, vec![(0, "{\"q\":1}".to_string())]);
    }

    #[test]
    fn pipelined_frames_in_one_segment_get_sequenced() {
        let (mut client, server) = pair();
        let pool = BufPool::new(8);
        let mut conn = Conn::new(server, 0, &pool);
        let mut frames = Vec::new();

        client.write_all(b"a\nb\n\nc\npartial").unwrap();
        std::thread::sleep(std::time::Duration::from_millis(5));
        conn.read_frames(&mut frames);
        let got: Vec<_> = frames.iter().map(|(s, t)| (*s, t.as_str())).collect();
        assert_eq!(got, vec![(0, "a"), (1, "b"), (2, "c")], "blank line skipped, partial held");

        frames.clear();
        client.write_all(b"-done\n").unwrap();
        std::thread::sleep(std::time::Duration::from_millis(5));
        conn.read_frames(&mut frames);
        assert_eq!(frames, vec![(3, "partial-done".to_string())]);
    }

    #[test]
    fn out_of_order_completions_write_in_request_order() {
        let (client, server) = pair();
        let pool = BufPool::new(8);
        let mut conn = Conn::new(server, 0, &pool);

        // Pretend three frames were read.
        let mut frames = Vec::new();
        (&client).write_all(b"x\ny\nz\n").unwrap();
        std::thread::sleep(std::time::Duration::from_millis(5));
        conn.read_frames(&mut frames);
        assert_eq!(frames.len(), 3);

        conn.complete(2, "r2");
        conn.complete(0, "r0");
        conn.complete(1, "r1");
        assert!(conn.flush());

        let mut reader = std::io::BufReader::new(&client);
        let mut out = String::new();
        use std::io::BufRead as _;
        for _ in 0..3 {
            reader.read_line(&mut out).unwrap();
        }
        assert_eq!(out, "r0\nr1\nr2\n");
        assert!(conn.inflight == 0 && conn.pending.is_empty());
    }

    #[test]
    fn inflight_cap_pauses_reading() {
        let (mut client, server) = pair();
        let pool = BufPool::new(8);
        let mut conn = Conn::new(server, 0, &pool);
        let mut frames = Vec::new();

        let mut blob = String::new();
        for i in 0..MAX_INFLIGHT + 10 {
            blob.push_str(&format!("req{i}\n"));
        }
        client.write_all(blob.as_bytes()).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(10));
        conn.read_frames(&mut frames);
        // Reading pauses once the cap is hit; the rest stays buffered or
        // in the kernel until completions free slots.
        assert!(frames.len() >= MAX_INFLIGHT);
        assert!(!conn.want_read(), "at/above inflight cap, reads pause");

        for (seq, _) in frames.drain(..) {
            conn.complete(seq, "ok");
        }
        assert!(conn.want_read(), "completions resume reading");
    }

    #[test]
    fn oversized_frame_is_rejected() {
        let (mut client, server) = pair();
        let pool = BufPool::new(8);
        let mut conn = Conn::new(server, 0, &pool);
        let mut frames = Vec::new();

        // Fake an almost-over-limit buffer without shipping 32 MiB
        // through loopback: preload rbuf as if reads had accumulated it,
        // then push it over the cap with real socket bytes.
        conn.rbuf = vec![b'x'; MAX_FRAME];
        conn.scan = conn.rbuf.len();
        client.write_all(b"spill").unwrap();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let status = conn.read_frames(&mut frames);
        assert_eq!(status, ReadStatus::FrameTooLong);
        assert!(frames.is_empty());
        assert!(conn.is_dead());
    }

    #[test]
    fn buffer_pool_recycles() {
        let pool = BufPool::new(4);
        let (client, server) = pair();
        let conn = Conn::new(server, 0, &pool);
        assert_eq!(pool.pooled(), 0);
        conn.recycle(&pool);
        assert_eq!(pool.pooled(), 2);
        drop(client);

        let b = pool.get();
        assert_eq!(pool.pooled(), 1);
        pool.put(b);
        assert_eq!(pool.pooled(), 2);

        // Oversized buffers are not pooled.
        pool.put(Vec::with_capacity(MAX_POOLED_BUF + 1));
        assert_eq!(pool.pooled(), 2);
    }
}
