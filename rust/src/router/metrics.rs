//! Serving metrics: counters + a fixed-bucket latency histogram, all
//! lock-free on the record path.

use std::sync::atomic::{AtomicU64, Ordering};

/// Log-spaced latency buckets in microseconds.
const BUCKETS_US: &[u64] = &[
    10, 20, 50, 100, 200, 500, 1_000, 2_000, 5_000, 10_000, 20_000, 50_000, 100_000, 500_000,
];

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub errors: AtomicU64,
    pub batches: AtomicU64,
    pub batched_queries: AtomicU64,
    pub rejected: AtomicU64,
    /// Connections accepted over the server's lifetime.
    pub connections: AtomicU64,
    /// Transient `accept(2)` failures (EMFILE, ECONNABORTED, ...) the
    /// accept path logged, backed off from, and survived.
    pub accept_errors: AtomicU64,
    /// Accepted connections refused because the per-connection thread
    /// could not be spawned (threads fallback mode only).
    pub spawn_failures: AtomicU64,
    latency_buckets: [AtomicU64; 15],
    latency_sum_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_latency_us(&self, us: u64) {
        let mut b = BUCKETS_US.len(); // overflow bucket
        for (i, &lim) in BUCKETS_US.iter().enumerate() {
            if us <= lim {
                b = i;
                break;
            }
        }
        self.latency_buckets[b].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
        self.responses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_queries.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Approximate percentile from the histogram (upper bucket edge).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let counts: Vec<u64> = self
            .latency_buckets
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return BUCKETS_US.get(i).copied().unwrap_or(1_000_000);
            }
        }
        1_000_000
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.responses.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_queries.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} responses={} errors={} rejected={} conns={} accept_errors={} spawn_failures={} batches={} mean_batch={:.2} mean_lat={:.0}us p50={}us p99={}us",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.connections.load(Ordering::Relaxed),
            self.accept_errors.load(Ordering::Relaxed),
            self.spawn_failures.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_latency_us(),
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(99.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_monotone() {
        let m = Metrics::new();
        for us in [5u64, 15, 80, 300, 2_000, 40_000] {
            m.record_latency_us(us);
        }
        let p50 = m.latency_percentile_us(50.0);
        let p99 = m.latency_percentile_us(99.0);
        assert!(p50 <= p99);
        assert!(p50 >= 10);
    }

    #[test]
    fn batch_accounting() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.mean_batch_size(), 6.0);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentile_us(99.0), 0);
        assert_eq!(m.mean_latency_us(), 0.0);
    }

    #[test]
    fn serving_plane_counters_surface_in_summary() {
        let m = Metrics::new();
        m.connections.fetch_add(3, Ordering::Relaxed);
        m.accept_errors.fetch_add(2, Ordering::Relaxed);
        m.spawn_failures.fetch_add(1, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("conns=3"), "{s}");
        assert!(s.contains("accept_errors=2"), "{s}");
        assert!(s.contains("spawn_failures=1"), "{s}");
    }
}
