//! The serving coordinator: TCP JSON-line frontend, dynamic batcher,
//! worker pool over a shared index, optional PJRT exact re-rank.
//!
//! Topology, epoll mode (the Linux default — one event-loop thread, a
//! fixed worker pool, no per-connection threads):
//!
//!   epoll loop ──frames──▶ Batcher ──next_batch──▶ worker threads
//!       ▲   ▲                                          │
//!       │   └── completions (mpsc + eventfd wake) ◀────┤
//!       └────── verb completions ◀── verb executor ◀───┘
//!
//! The loop (`EventLoop`) owns every connection as a [`crate::router::conn::Conn`]
//! state machine: nonblocking reads feed the incremental framer, parsed
//! queries go to the shared [`Batcher`], mutation/introspection verbs go
//! to a dedicated executor thread (they can block on WAL fsync or
//! replication acks), and completions flow back over an mpsc channel
//! paired with an eventfd [`crate::router::poll::Waker`]. Responses to
//! pipelined requests are re-sequenced per connection so clients always
//! see answers in request order. `--serve-mode threads` keeps the
//! original thread-per-connection loop as a fallback (and the only mode
//! off Linux).
//!
//! Workers own their scratch (a pooled `SearchContext`) and search the
//! shared [`ServeIndex`] — any [`AnnIndex`] implementor, so the same
//! server binary fronts HNSW, HNSW-FINGER, Vamana, NN-descent, IVF-PQ, or
//! brute force. The index sits behind an `RwLock`: search batches take
//! shared read locks on the worker pool while the mutation verbs
//! (`INSERT`/`DELETE`/`COMPACT`) take brief write locks — live updates
//! and query traffic interleave on one server. The optional PJRT `rerank`
//! executable re-scores the candidate set through the AOT JAX/Pallas
//! artifact so final distances come from the L1 kernel (exactness
//! cross-check + the "Python-free request path" demonstration).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::{Duration, Instant};

use crate::core::matrix::Matrix;
use crate::index::{AnnIndex, SearchContext, SearchParams, DEFAULT_COMPACT_THRESHOLD};
use crate::repl::cluster::ClusterNode;
use crate::repl::hub::ReplHub;
use crate::repl::replica::ReplMetrics;
use crate::router::batcher::{Batcher, SubmitError};
use crate::router::conn::{BufPool, Conn, ReadStatus};
use crate::router::metrics::Metrics;
use crate::router::poll::{self, Poller, Waker};
use crate::router::protocol::{
    error_line, request_id_hint, session_min_seq, stale_line, warming_line, FingerprintInfo,
    MutOutcome, MutResponse, QueryRequest, QueryResponse, Request,
};
use crate::runtime::service::RerankService;
use crate::wal::{Wal, WalOp, WalWriter};

// Poison-tolerant lock acquisition. A panic inside one mutation handler
// used to poison the index lock and turn every subsequent request on
// every connection into a panic of its own; recovering the guard keeps
// the server answering (the panicking request itself is reported as a
// structured in-band error by `mutate`).
fn rlock<T: ?Sized>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

fn wlock<T: ?Sized>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| e.into_inner())
}

fn mlock<T: ?Sized>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Shared serving state: any index family behind one API. Reads (search)
/// run concurrently; the mutation verbs serialize behind the write lock.
///
/// Note on compaction: `compact()` rebuilds the index under the write
/// lock, so search traffic stalls for the duration of the rebuild — an
/// explicit availability tradeoff at this scale (a snapshot-and-swap
/// compactor can lift it later without changing the protocol).
pub struct ServeIndex {
    pub index: RwLock<Box<dyn AnnIndex>>,
    /// Serving-time defaults; `k` is overridden per request.
    pub params: SearchParams,
    /// Pooled scratch for the mutation path (one mutation at a time —
    /// they hold the write lock — so one context suffices and inserts
    /// reuse warm buffers instead of allocating under the lock).
    mut_ctx: Mutex<SearchContext>,
    /// Set once any mutation verb succeeds. The PJRT rerank path indexes
    /// a startup snapshot of the data matrix by id, which stops being
    /// valid the moment ids and rows can diverge — so rerank is bypassed
    /// from then on.
    mutated: AtomicBool,
    /// Optional durability plane: when present, every applied mutation is
    /// appended under the index write lock (so WAL order == apply order)
    /// and committed per the fsync policy before the verb is
    /// acknowledged.
    wal: Option<Arc<Wal>>,
    /// Optional replication hub (leader role): applied+logged ops are
    /// published to connected replicas under the same write lock, and the
    /// client ack additionally waits for the configured replication
    /// level. Behind a mutex because cluster failover swaps it at
    /// runtime (promotion installs a hub, demotion removes it).
    repl: Mutex<Option<Arc<ReplHub>>>,
    /// Replica role: mutation verbs are refused (the replication stream
    /// is the only writer); searches and the read-only introspection
    /// verbs serve normally.
    read_only: bool,
    /// Cluster supervisor, when this node runs under leader election.
    /// Mutations consult its role check, and `repl_status` reports the
    /// elected role/term/leader.
    cluster: Mutex<Option<Arc<ClusterNode>>>,
    /// True when this index was built for cluster mode but the
    /// supervisor has not been attached yet — mutations fail fast
    /// instead of sneaking through the startup window unfenced.
    cluster_pending: bool,
    /// One-way readiness latch. Starts false only for warm-up roles
    /// (`as_replica`): the query listener binds immediately and answers
    /// structured `warming` errors until catch-up flips this.
    ready: AtomicBool,
    /// Follower-stream counters for `repl_status` (attached by the
    /// serve wiring when this node replicates from a leader).
    repl_metrics: Mutex<Option<Arc<ReplMetrics>>>,
    /// Last op sequence applied to the live index (via local mutation or
    /// the replication stream). Reported by `fingerprint`/`repl_status`.
    applied_seq: AtomicU64,
}

impl ServeIndex {
    pub fn new(index: Box<dyn AnnIndex>, ef_search: usize) -> ServeIndex {
        ServeIndex::with_params(index, SearchParams::new(10).with_ef(ef_search))
    }

    pub fn with_params(index: Box<dyn AnnIndex>, params: SearchParams) -> ServeIndex {
        ServeIndex {
            index: RwLock::new(index),
            params,
            mut_ctx: Mutex::new(SearchContext::new()),
            mutated: AtomicBool::new(false),
            wal: None,
            repl: Mutex::new(None),
            read_only: false,
            cluster: Mutex::new(None),
            cluster_pending: false,
            ready: AtomicBool::new(true),
            repl_metrics: Mutex::new(None),
            applied_seq: AtomicU64::new(0),
        }
    }

    /// Attach a durability plane: mutations append + commit before ack,
    /// and the `save` verb checkpoints through it.
    pub fn with_wal(mut self, wal: Arc<Wal>) -> ServeIndex {
        self.wal = Some(wal);
        self
    }

    /// Attach a replication hub (leader role): every applied+logged op
    /// is streamed to connected replicas, and acks gate on the hub's
    /// level. Requires a WAL (the hub streams from it).
    pub fn with_repl(self, hub: Arc<ReplHub>) -> ServeIndex {
        *mlock(&self.repl) = Some(hub);
        self
    }

    /// Mark this server a replica: reads serve once caught up (queries
    /// answer a structured `warming` error until then), writes are
    /// refused (the replication stream applies mutations via
    /// [`ServeIndex::apply_replicated`]).
    pub fn as_replica(mut self) -> ServeIndex {
        self.read_only = true;
        self.ready = AtomicBool::new(false);
        self
    }

    /// Mark this index as serving under a cluster supervisor. Until
    /// [`ServeIndex::set_cluster`] attaches one, mutations fail fast —
    /// the role fence must never be absent in cluster mode. The node
    /// serves reads from its recovered local state throughout (graceful
    /// degradation: elections stall writes, never reads).
    pub fn in_cluster(mut self) -> ServeIndex {
        self.cluster_pending = true;
        self
    }

    /// Install/replace the replication hub at runtime (cluster
    /// promotion installs one, demotion removes it).
    pub fn set_hub(&self, hub: Option<Arc<ReplHub>>) {
        *mlock(&self.repl) = hub;
    }

    /// Attach the cluster supervisor (resolves the `in_cluster` fence).
    pub fn set_cluster(&self, node: Arc<ClusterNode>) {
        *mlock(&self.cluster) = Some(node);
    }

    pub fn cluster(&self) -> Option<Arc<ClusterNode>> {
        mlock(&self.cluster).clone()
    }

    /// Flip the readiness latch (one-way). Called when a replica
    /// catches up to the leader's stream, or when a node wins election.
    pub fn set_ready(&self) {
        self.ready.store(true, Ordering::SeqCst);
    }

    pub fn is_ready(&self) -> bool {
        self.ready.load(Ordering::SeqCst)
    }

    /// Expose follower-stream counters through `repl_status`.
    pub fn set_repl_metrics(&self, m: Arc<ReplMetrics>) {
        *mlock(&self.repl_metrics) = Some(m);
    }

    pub fn repl_metrics(&self) -> Option<Arc<ReplMetrics>> {
        mlock(&self.repl_metrics).clone()
    }

    /// Seed the applied-sequence counter (e.g. after WAL recovery).
    pub fn set_applied_seq(&self, seq: u64) {
        self.applied_seq.store(seq, Ordering::SeqCst);
    }

    pub fn applied_seq(&self) -> u64 {
        self.applied_seq.load(Ordering::SeqCst)
    }

    /// The live replication hub, if this node currently leads. Owned
    /// clone: failover may swap the slot while the caller holds one.
    pub fn repl_hub(&self) -> Option<Arc<ReplHub>> {
        mlock(&self.repl).clone()
    }

    pub fn is_read_only(&self) -> bool {
        self.read_only
    }

    pub fn wal(&self) -> Option<&Arc<Wal>> {
        self.wal.as_ref()
    }

    /// Has any mutation verb been applied? (Disables the snapshot-based
    /// PJRT rerank path.)
    pub fn is_mutated(&self) -> bool {
        self.mutated.load(Ordering::Acquire)
    }

    pub fn search(&self, q: &[f32], k: usize, ctx: &mut SearchContext) -> Vec<(f32, u32)> {
        let mut p = self.params.clone();
        p.k = k;
        rlock(&self.index)
            .search(q, &p, ctx)
            .into_iter()
            .map(|n| (n.dist, n.id))
            .collect()
    }

    /// Apply one mutation verb under the write lock. Non-mutable families,
    /// stale ids, and even panicking handlers produce structured errors,
    /// never dropped connections. With a WAL attached the op is appended
    /// under the lock (WAL order == apply order) and made durable per the
    /// fsync policy *before* the acknowledgement — commit happens after
    /// the lock drops, so concurrent committers share fsyncs (group
    /// commit). Compaction rebuilds inline (see the struct docs for the
    /// tradeoff).
    pub fn mutate(&self, req: &Request) -> Result<MutResponse, String> {
        if self.read_only {
            return Err("replica is read-only; send writes to the primary".into());
        }
        // Cluster role fence: only the elected leader takes writes, and a
        // demoted leader must start refusing the moment its term is
        // superseded — this check runs before any state is touched.
        if self.cluster_pending {
            match self.cluster() {
                Some(c) => c.check_writable()?,
                None => {
                    return Err(
                        "cluster initializing; writes unavailable until the role fence is up"
                            .into(),
                    )
                }
            }
        }
        // Snapshot the hub once: failover may swap it mid-verb, and the
        // publish and the ack wait must talk to the same hub.
        let hub = self.repl_hub();
        if let Request::Save { id } = req {
            let (seq, live) = self.save()?;
            return Ok(MutResponse { id: *id, outcome: MutOutcome::Saved(seq), live, seq });
        }
        let mut pending: Option<(Arc<WalWriter>, u64)> = None;
        let (outcome, live) = {
            let mut guard = wlock(&self.index);
            let dim = guard.dim();
            let name = guard.name();
            let Some(index) = guard.as_mutable() else {
                return Err(format!("index family '{name}' does not support mutation"));
            };
            let mut ctx = mlock(&self.mut_ctx);
            let ctx = &mut *ctx;
            // Catch panics so one bad request cannot take down the server
            // (and, with the poison-tolerant guards above, cannot wedge
            // the lock for everyone else). A panicked op is NOT logged:
            // the WAL only ever holds ops that completed, which is what
            // lets recovery replay unconditionally.
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || -> Result<MutOutcome, String> {
                    Ok(match req {
                        Request::Insert { vector, .. } => {
                            if vector.len() != dim {
                                return Err(format!(
                                    "dim mismatch: got {}, want {dim}",
                                    vector.len()
                                ));
                            }
                            let key = index.insert(vector, ctx).map_err(|e| e.to_string())?;
                            MutOutcome::Inserted(key)
                        }
                        Request::Delete { key, .. } => {
                            index.remove(*key).map_err(|e| e.to_string())?;
                            MutOutcome::Deleted(*key)
                        }
                        Request::Compact { .. } => {
                            MutOutcome::Compacted(index.compact(ctx).map_err(|e| e.to_string())?)
                        }
                        Request::SetThreshold { frac, .. } => {
                            index.set_compact_threshold(*frac);
                            MutOutcome::ThresholdSet(*frac)
                        }
                        Request::Query(_)
                        | Request::Save { .. }
                        | Request::Fingerprint { .. }
                        | Request::ReplStatus { .. } => return Err("not a mutation".into()),
                    })
                },
            ))
            .map_err(|_| "mutation handler panicked; op not applied to the log".to_string())??;
            // Applied: append before acking, still under the index lock.
            // Compact is logged even when the threshold gate declined —
            // the gate is deterministic, so replay declines identically.
            if let Some(wal) = &self.wal {
                let op = match req {
                    Request::Insert { vector, .. } => WalOp::Insert { vector: vector.clone() },
                    Request::Delete { key, .. } => WalOp::Delete { key: *key },
                    Request::Compact { .. } => WalOp::Compact,
                    Request::SetThreshold { frac, .. } => WalOp::SetThreshold { frac: *frac },
                    Request::Query(_)
                    | Request::Save { .. }
                    | Request::Fingerprint { .. }
                    | Request::ReplStatus { .. } => unreachable!(),
                };
                let (w, seq) =
                    wal.append(&op).map_err(|e| format!("wal append failed: {e}"))?;
                // Publish to replicas under the same lock that ordered the
                // append: stream order == log order == apply order.
                if let Some(hub) = &hub {
                    hub.publish(seq, &op);
                }
                self.applied_seq.store(seq, Ordering::SeqCst);
                pending = Some((w, seq));
            }
            // A compact that declined to rebuild changed nothing, and a
            // threshold change moves no vectors; everything else
            // invalidates the rerank snapshot.
            if !matches!(
                outcome,
                MutOutcome::Compacted(false) | MutOutcome::ThresholdSet(_)
            ) {
                self.mutated.store(true, Ordering::Release);
            }
            (outcome, index.live_len() as u64)
        };
        // Durability before acknowledgement, outside the index lock so
        // concurrent committers coalesce onto one fsync.
        let mut acked_seq = 0;
        if let Some((w, seq)) = pending {
            w.commit(seq).map_err(|e| format!("wal commit failed: {e}"))?;
            // Replication gate: the client ack also waits for the
            // configured replication level (`none` returns immediately;
            // `quorum` needs a majority of the cluster durably fsynced,
            // counting this node). On timeout or lost quorum the op is
            // still applied+logged locally — the error reports exactly
            // that ambiguity.
            if let Some(hub) = &hub {
                hub.wait_acked(seq)?;
            }
            acked_seq = seq;
        }
        Ok(MutResponse { id: req.id(), outcome, live, seq: acked_seq })
    }

    /// Checkpoint the serving index through the WAL: fresh snapshot + log
    /// rotation, under the write lock so the cut is quiescent. Returns
    /// the new snapshot sequence and the live count.
    ///
    /// The v5 bundle does not persist the compaction threshold, so when
    /// the live index runs a non-default one it is re-logged as the first
    /// op of the fresh generation (and streamed to replicas) — replay and
    /// catch-up then gate compaction exactly as the live run does.
    pub fn save(&self) -> Result<(u64, u64), String> {
        let Some(wal) = &self.wal else {
            return Err("snapshot requires a WAL (serve --wal-dir)".into());
        };
        let guard = wlock(&self.index);
        let seq = wal
            .checkpoint(guard.as_ref())
            .map_err(|e| format!("checkpoint failed: {e}"))?;
        let threshold = guard.as_mutable_view().map(|v| v.compact_threshold());
        if let Some(frac) = threshold.filter(|f| *f != DEFAULT_COMPACT_THRESHOLD) {
            let op = WalOp::SetThreshold { frac };
            let (w, tseq) = wal
                .append(&op)
                .map_err(|e| format!("threshold re-log failed: {e}"))?;
            if let Some(hub) = self.repl_hub() {
                hub.publish(tseq, &op);
            }
            self.applied_seq.store(tseq, Ordering::SeqCst);
            w.commit(tseq).map_err(|e| format!("threshold re-log commit failed: {e}"))?;
        }
        let live = guard
            .as_mutable_view()
            .map_or(guard.len() as u64, |v| v.live_len() as u64);
        Ok((seq, live))
    }

    /// Swap in a whole new index (replica snapshot install / recovery).
    /// Takes the write lock, so in-flight search batches finish against
    /// the old state and later ones see the new.
    pub fn install(&self, index: Box<dyn AnnIndex>, seq: u64) {
        let mut guard = wlock(&self.index);
        *guard = index;
        self.applied_seq.store(seq, Ordering::SeqCst);
        // The rerank snapshot (if any) was taken against the boot-time
        // index; a wholesale swap invalidates it just like a mutation.
        self.mutated.store(true, Ordering::Release);
    }

    /// Apply one op from the replication stream: same verbs, same
    /// ordering discipline as [`ServeIndex::mutate`], but the sequence
    /// number is the primary's, and the local WAL (when the replica keeps
    /// one) must land it at exactly that sequence — a mismatch means the
    /// local log diverged from the stream and is a hard error, not a
    /// retry.
    pub fn apply_replicated(
        &self,
        seq: u64,
        op: &WalOp,
        wal: Option<&Wal>,
    ) -> Result<(), String> {
        let mut guard = wlock(&self.index);
        let name = guard.name();
        let Some(index) = guard.as_mutable() else {
            return Err(format!("index family '{name}' does not support mutation"));
        };
        let mut ctx = mlock(&self.mut_ctx);
        let ctx = &mut *ctx;
        match op {
            WalOp::Insert { vector } => {
                index.insert(vector, ctx).map_err(|e| e.to_string())?;
            }
            WalOp::Delete { key } => {
                index.remove(*key).map_err(|e| e.to_string())?;
            }
            WalOp::Compact => {
                index.compact(ctx).map_err(|e| e.to_string())?;
            }
            WalOp::SetThreshold { frac } => index.set_compact_threshold(*frac),
        }
        self.mutated.store(true, Ordering::Release);
        if let Some(wal) = wal {
            let (w, lseq) = wal.append(op).map_err(|e| format!("local append failed: {e}"))?;
            if lseq != seq {
                return Err(format!(
                    "local WAL diverged: primary seq {seq}, local append landed at {lseq}"
                ));
            }
            // Durable before the ack goes back — with `--fsync-policy
            // always` this is what makes level-`all` acks survive a
            // primary SIGKILL.
            w.commit(lseq).map_err(|e| format!("local commit failed: {e}"))?;
        }
        self.applied_seq.store(seq, Ordering::SeqCst);
        Ok(())
    }

    /// Hash the live index's persisted-bundle bytes (read lock only).
    /// Determinism makes equal fingerprints mean byte-identical state.
    pub fn fingerprint(&self, id: u64) -> Result<FingerprintInfo, String> {
        let guard = rlock(&self.index);
        let fingerprint = crate::repl::bundle_fingerprint(guard.as_ref())
            .map_err(|e| format!("fingerprint failed: {e}"))?;
        let live = guard
            .as_mutable_view()
            .map_or(guard.len() as u64, |v| v.live_len() as u64);
        Ok(FingerprintInfo { id, fingerprint, seq: self.applied_seq(), live })
    }

    /// JSON line for the `repl_status` verb: role, applied sequence,
    /// warm-up state, per-replica ack progress when this node streams to
    /// replicas, election facts (term, who leads, where to send writes)
    /// when it runs under a cluster, and follower-stream counters when
    /// it replicates from a leader.
    ///
    /// Works against any node — followers relay the leader's advertised
    /// addresses out of the heartbeats, which is what lets `repl status`
    /// and leader discovery target whichever node answers first.
    pub fn repl_status_json(&self, id: u64) -> String {
        use crate::core::json::Json;
        let mut fields = vec![
            ("id", Json::Num(id as f64)),
            ("seq", Json::Num(self.applied_seq() as f64)),
            ("state", Json::str(if self.is_ready() { "ready" } else { "warming" })),
        ];
        let hub = self.repl_hub();
        match self.cluster() {
            Some(c) => {
                fields.push(("role", Json::str(c.role().name())));
                fields.push(("node", Json::Num(c.id() as f64)));
                fields.push(("term", Json::Num(c.term() as f64)));
                match c.leader() {
                    Some(l) => {
                        fields.push(("leader", Json::Num(l.id as f64)));
                        fields.push(("leader_query", Json::str(&l.query_addr)));
                        fields.push(("leader_repl", Json::str(&l.repl_addr)));
                    }
                    None => fields.push(("leader", Json::Null)),
                }
            }
            None if hub.is_some() => fields.push(("role", Json::str("primary"))),
            None if self.read_only => fields.push(("role", Json::str("replica"))),
            None => fields.push(("role", Json::str("standalone"))),
        }
        if let Some(hub) = &hub {
            fields.push(("ack_level", Json::str(hub.level().name())));
            fields.push(("expect", Json::Num(hub.expect() as f64)));
            let replicas = hub
                .status()
                .into_iter()
                .map(|r| {
                    Json::obj(vec![
                        ("id", Json::Num(r.id as f64)),
                        ("acked", Json::Num(r.acked as f64)),
                        ("enqueued", Json::Num(r.enqueued as f64)),
                    ])
                })
                .collect();
            fields.push(("replicas", Json::Arr(replicas)));
        }
        let metrics = self
            .repl_metrics()
            .or_else(|| self.cluster().and_then(|c| c.replica_metrics()));
        if let Some(m) = metrics {
            use std::sync::atomic::Ordering::Relaxed;
            fields.push((
                "replica_metrics",
                Json::obj(vec![
                    (
                        "reconnect_attempts",
                        Json::Num(m.reconnect_attempts.load(Relaxed) as f64),
                    ),
                    (
                        "reconnects_completed",
                        Json::Num(m.reconnects_completed.load(Relaxed) as f64),
                    ),
                    (
                        "snapshots_installed",
                        Json::Num(m.snapshots_installed.load(Relaxed) as f64),
                    ),
                    ("violations", Json::Num(m.violations.load(Relaxed) as f64)),
                    ("last_backoff_ms", Json::Num(m.last_backoff_ms.load(Relaxed) as f64)),
                ]),
            ));
        }
        Json::obj(fields).to_string()
    }

    /// Copy of one data row (test/bench convenience; takes the read lock).
    pub fn row(&self, i: usize) -> Vec<f32> {
        rlock(&self.index).data().row(i).to_vec()
    }

    /// Clone of the whole data matrix (rerank service setup).
    pub fn data_clone(&self) -> Matrix {
        rlock(&self.index).data().clone()
    }

    pub fn dim(&self) -> usize {
        rlock(&self.index).dim()
    }

    pub fn len(&self) -> usize {
        rlock(&self.index).len()
    }

    pub fn is_empty(&self) -> bool {
        rlock(&self.index).is_empty()
    }
}

/// A finished response on its way back to the epoll loop: which
/// connection slot (plus the slot's generation, so answers for a closed
/// connection whose slot was reused get discarded) and which pipelined
/// frame this line answers.
pub struct Completion {
    slot: usize,
    gen: u64,
    seq: u64,
    line: String,
}

/// Where a worker delivers a query's response: an mpsc channel (blocking
/// connection threads and `submit_local`) or the event loop's completion
/// queue plus an eventfd wake.
pub enum Responder {
    Channel(mpsc::Sender<QueryResponse>),
    Event {
        slot: usize,
        gen: u64,
        seq: u64,
        done: mpsc::Sender<Completion>,
        waker: Arc<Waker>,
    },
}

impl Responder {
    fn respond(&self, resp: QueryResponse) {
        match self {
            // Receiver may have hung up; that's fine.
            Responder::Channel(tx) => {
                let _ = tx.send(resp);
            }
            Responder::Event { slot, gen, seq, done, waker } => {
                let _ = done.send(Completion {
                    slot: *slot,
                    gen: *gen,
                    seq: *seq,
                    line: resp.to_json_line(),
                });
                waker.wake();
            }
        }
    }
}

/// One queued query with its response path.
pub struct Job {
    pub req: QueryRequest,
    pub submitted: Instant,
    pub resp: Responder,
}

/// A non-query verb routed off the event loop (mutations can block for
/// seconds on WAL fsync or replication acks; the loop never waits).
struct VerbJob {
    slot: usize,
    gen: u64,
    seq: u64,
    req: Request,
}

/// How the frontend multiplexes connections.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeMode {
    /// One blocking thread per connection (portable fallback).
    Threads,
    /// One nonblocking epoll event loop for all connections (Linux).
    Epoll,
}

impl Default for ServeMode {
    fn default() -> ServeMode {
        if poll::SUPPORTED {
            ServeMode::Epoll
        } else {
            ServeMode::Threads
        }
    }
}

impl ServeMode {
    pub fn parse(s: &str) -> Result<ServeMode, String> {
        match s {
            "threads" => Ok(ServeMode::Threads),
            "epoll" => Ok(ServeMode::Epoll),
            other => Err(format!("unknown serve mode '{other}' (expected threads|epoll)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ServeMode::Threads => "threads",
            ServeMode::Epoll => "epoll",
        }
    }
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub max_queue: usize,
    /// Re-rank candidates through the PJRT artifact when available.
    pub use_pjrt_rerank: bool,
    /// Connection multiplexing: epoll event loop (Linux default) or
    /// thread-per-connection fallback.
    pub mode: ServeMode,
    /// Max read/write buffers the epoll loop keeps pooled for reuse
    /// across connections (two per live connection while open).
    pub buf_pool: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7771".into(),
            workers: 4,
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            max_queue: 4096,
            use_pjrt_rerank: false,
            mode: ServeMode::default(),
            buf_pool: 1024,
        }
    }
}

/// Capped exponential backoff for transient accept errors (EMFILE and
/// friends): the accept loop must never die — it logs, waits, retries.
fn accept_backoff(streak: u32) -> Duration {
    Duration::from_millis((1u64 << streak.min(6)).min(50))
}

#[cfg(test)]
static INJECT_SPAWN_FAILURES: AtomicU64 = AtomicU64::new(0);

/// Test hook: pretend `thread::Builder::spawn` failed for the next N
/// accepted connections (real triggers — RLIMIT_NPROC exhaustion — are
/// too invasive to induce in a shared test process).
#[cfg(test)]
fn injected_spawn_failure() -> bool {
    let mut n = INJECT_SPAWN_FAILURES.load(Ordering::Relaxed);
    while n > 0 {
        match INJECT_SPAWN_FAILURES.compare_exchange(
            n,
            n - 1,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return true,
            Err(cur) => n = cur,
        }
    }
    false
}

#[cfg(not(test))]
fn injected_spawn_failure() -> bool {
    false
}

/// A running server (handle for shutdown + metrics).
pub struct Server {
    pub metrics: Arc<Metrics>,
    pub local_addr: std::net::SocketAddr,
    batcher: Arc<Batcher<Job>>,
    stop: Arc<AtomicBool>,
    /// Present in epoll mode: kicks the event loop out of `epoll_pwait`
    /// so shutdown doesn't wait out the poll timeout.
    waker: Option<Arc<Waker>>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start listening + worker pool. `rerank` is an optional PJRT
    /// executor service (a dedicated thread owning the compiled artifact;
    /// see `runtime::service`) shared by all workers. With
    /// `config.mode == Epoll` on an unsupported target this returns the
    /// underlying `Unsupported` error — callers wanting the automatic
    /// fallback should use `ServeMode::default()`.
    pub fn start(
        index: Arc<ServeIndex>,
        config: ServerConfig,
        rerank: Option<Arc<RerankService>>,
    ) -> std::io::Result<Server> {
        let listener = TcpListener::bind(&config.addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let metrics = Arc::new(Metrics::new());
        let batcher: Arc<Batcher<Job>> = Arc::new(Batcher::new(
            config.max_batch,
            config.max_wait,
            config.max_queue,
        ));
        let stop = Arc::new(AtomicBool::new(false));
        let mut threads = Vec::new();

        // Worker pool (shared by both modes; the Responder enum routes
        // each response to its connection thread or the event loop).
        for wid in 0..config.workers.max(1) {
            let batcher = Arc::clone(&batcher);
            let index = Arc::clone(&index);
            let metrics = Arc::clone(&metrics);
            let rerank = rerank.clone();
            let use_rerank = config.use_pjrt_rerank;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("finger-worker-{wid}"))
                    .spawn(move || {
                        let mut ctx = SearchContext::for_universe(index.len());
                        while let Some(batch) = batcher.next_batch() {
                            metrics.record_batch(batch.len());
                            let all_hits = batch_hits(&index, &batch, &mut ctx);
                            // The rerank service scores against a startup
                            // snapshot of the data matrix indexed by id;
                            // once a mutation lands, ids and snapshot rows
                            // can diverge, so the exact-rerank pass is
                            // bypassed rather than served wrong.
                            let rerank_ok = use_rerank && !index.is_mutated();
                            for (job, hits) in batch.into_iter().zip(all_hits) {
                                let hits = match (&rerank, rerank_ok) {
                                    (Some(svc), true) => {
                                        let ids: Vec<u32> =
                                            hits.iter().map(|&(_, id)| id).collect();
                                        svc.rerank(&job.req.vector, &ids, job.req.k)
                                            .unwrap_or(hits)
                                    }
                                    _ => hits,
                                };
                                let latency_us = job.submitted.elapsed().as_micros() as u64;
                                metrics.record_latency_us(latency_us);
                                job.resp.respond(QueryResponse {
                                    id: job.req.id,
                                    hits,
                                    latency_us,
                                });
                            }
                        }
                    })
                    .unwrap(),
            );
        }

        let waker = match config.mode {
            ServeMode::Epoll => {
                let poller = Poller::new()?;
                let waker = Arc::new(Waker::new()?);
                poller.add(listener.as_raw_fd(), TOKEN_LISTENER, true, false)?;
                poller.add(waker.raw_fd(), TOKEN_WAKER, true, false)?;
                let (comp_tx, comp_rx) = mpsc::channel();
                let (verbs_tx, verbs_rx) = mpsc::channel::<VerbJob>();

                // Verb executor: mutations / fingerprint / repl_status can
                // block (write lock, WAL fsync, replication acks), so they
                // run here, never on the event loop. One thread also keeps
                // a connection's verbs applied in submission order.
                {
                    let index = Arc::clone(&index);
                    let metrics = Arc::clone(&metrics);
                    let comp_tx = comp_tx.clone();
                    let waker = Arc::clone(&waker);
                    threads.push(
                        std::thread::Builder::new()
                            .name("finger-verbs".into())
                            .spawn(move || {
                                while let Ok(job) = verbs_rx.recv() {
                                    let line = verb_reply(&index, &metrics, &job.req);
                                    if comp_tx
                                        .send(Completion {
                                            slot: job.slot,
                                            gen: job.gen,
                                            seq: job.seq,
                                            line,
                                        })
                                        .is_err()
                                    {
                                        break;
                                    }
                                    waker.wake();
                                }
                            })?,
                    );
                }

                let dim = index.dim();
                let event_loop = EventLoop {
                    listener,
                    poller,
                    waker: Arc::clone(&waker),
                    index,
                    batcher: Arc::clone(&batcher),
                    metrics: Arc::clone(&metrics),
                    stop: Arc::clone(&stop),
                    pool: BufPool::new(config.buf_pool),
                    comp_tx,
                    comp_rx,
                    verbs_tx,
                    conns: Vec::new(),
                    free: Vec::new(),
                    next_gen: 0,
                    accept_streak: 0,
                    dim,
                };
                threads.push(
                    std::thread::Builder::new()
                        .name("finger-epoll".into())
                        .spawn(move || event_loop.run())?,
                );
                Some(waker)
            }
            ServeMode::Threads => {
                let batcher = Arc::clone(&batcher);
                let metrics = Arc::clone(&metrics);
                let stop = Arc::clone(&stop);
                let index = Arc::clone(&index);
                threads.push(
                    std::thread::Builder::new()
                        .name("finger-accept".into())
                        .spawn(move || {
                            let mut conn_id = 0u64;
                            let mut streak = 0u32;
                            loop {
                                if stop.load(Ordering::Relaxed) {
                                    break;
                                }
                                match listener.accept() {
                                    Ok((stream, _)) => {
                                        streak = 0;
                                        // BSD-family targets inherit the
                                        // listener's O_NONBLOCK on accept;
                                        // connection threads read blocking.
                                        let _ = stream.set_nonblocking(false);
                                        let _ = stream.set_nodelay(true);
                                        metrics.connections.fetch_add(1, Ordering::Relaxed);
                                        // Clone a writer *before* the spawn
                                        // so a spawn failure can still be
                                        // reported in-band (the closure —
                                        // and the stream it owns — is
                                        // dropped when spawn errors).
                                        let refusal = stream.try_clone();
                                        let batcher = Arc::clone(&batcher);
                                        let conn_metrics = Arc::clone(&metrics);
                                        let index = Arc::clone(&index);
                                        let cid = conn_id;
                                        conn_id += 1;
                                        let spawned: std::io::Result<()> =
                                            if injected_spawn_failure() {
                                                Err(std::io::Error::new(
                                                    std::io::ErrorKind::WouldBlock,
                                                    "injected spawn failure",
                                                ))
                                            } else {
                                                std::thread::Builder::new()
                                                    .name(format!("finger-conn-{cid}"))
                                                    .spawn(move || {
                                                        handle_conn(
                                                            stream,
                                                            &batcher,
                                                            &conn_metrics,
                                                            &index,
                                                        )
                                                    })
                                                    .map(|_| ())
                                            };
                                        if let Err(e) = spawned {
                                            metrics
                                                .spawn_failures
                                                .fetch_add(1, Ordering::Relaxed);
                                            metrics.errors.fetch_add(1, Ordering::Relaxed);
                                            if let Ok(mut w) = refusal {
                                                let _ = writeln!(
                                                    w,
                                                    "{}",
                                                    error_line(
                                                        0,
                                                        &format!(
                                                            "cannot serve connection: {e}"
                                                        )
                                                    )
                                                );
                                            }
                                        }
                                    }
                                    Err(ref e)
                                        if e.kind() == std::io::ErrorKind::WouldBlock =>
                                    {
                                        std::thread::sleep(Duration::from_millis(2));
                                    }
                                    Err(e) => {
                                        // Transient failure (EMFILE under fd
                                        // pressure, ECONNABORTED, ...): the
                                        // accept loop must outlive it. Log,
                                        // back off, retry; only `stop` ends
                                        // the loop.
                                        metrics.accept_errors.fetch_add(1, Ordering::Relaxed);
                                        eprintln!("finger-serve: accept error (retrying): {e}");
                                        std::thread::sleep(accept_backoff(streak));
                                        streak = streak.saturating_add(1);
                                    }
                                }
                            }
                        })
                        .unwrap(),
                );
                None
            }
        };

        Ok(Server {
            metrics,
            local_addr,
            batcher,
            stop,
            waker,
            threads,
        })
    }

    /// Submit a query in-process (bypasses TCP; used by benches/tests).
    pub fn submit_local(
        &self,
        req: QueryRequest,
    ) -> Result<mpsc::Receiver<QueryResponse>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.batcher.submit(Job {
            req,
            submitted: Instant::now(),
            resp: Responder::Channel(tx),
        })?;
        Ok(rx)
    }

    pub fn shutdown(self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(waker) = &self.waker {
            waker.wake();
        }
        self.batcher.close();
        for t in self.threads {
            let _ = t.join();
        }
    }
}

/// Sentinel poller tokens for the two non-connection fds. Connection
/// tokens are slab slot indexes, which stay far below these.
const TOKEN_LISTENER: u64 = u64::MAX;
const TOKEN_WAKER: u64 = u64::MAX - 1;

/// The epoll frontend: one thread multiplexing every connection.
struct EventLoop {
    listener: TcpListener,
    poller: Poller,
    waker: Arc<Waker>,
    index: Arc<ServeIndex>,
    batcher: Arc<Batcher<Job>>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    pool: BufPool,
    comp_tx: mpsc::Sender<Completion>,
    comp_rx: mpsc::Receiver<Completion>,
    verbs_tx: mpsc::Sender<VerbJob>,
    /// Connection slab; the poller token for a connection is its slot.
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    next_gen: u64,
    accept_streak: u32,
    dim: usize,
}

impl EventLoop {
    fn run(mut self) {
        let mut events = Vec::new();
        let mut frames: Vec<(u64, String)> = Vec::new();
        loop {
            if self.stop.load(Ordering::Relaxed) {
                break;
            }
            if self.poller.wait(&mut events, 500).is_err() {
                break;
            }
            for ev in events.iter().copied() {
                match ev.token {
                    TOKEN_LISTENER => self.accept_burst(),
                    TOKEN_WAKER => self.waker.drain(),
                    slot => self.conn_event(slot as usize, ev.errhup, &mut frames),
                }
            }
            self.drain_completions();
        }
    }

    /// Accept until the listener drains. Transient errors (EMFILE, ...)
    /// are counted, logged, and backed off — the listener stays armed
    /// (level-triggered), so the next `epoll_pwait` retries.
    fn accept_burst(&mut self) {
        loop {
            if self.stop.load(Ordering::Relaxed) {
                return;
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    self.accept_streak = 0;
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let slot = self.free.pop().unwrap_or_else(|| {
                        self.conns.push(None);
                        self.conns.len() - 1
                    });
                    self.next_gen += 1;
                    let conn = Conn::new(stream, self.next_gen, &self.pool);
                    if self
                        .poller
                        .add(conn.stream.as_raw_fd(), slot as u64, true, false)
                        .is_err()
                    {
                        self.free.push(slot);
                        continue;
                    }
                    self.metrics.connections.fetch_add(1, Ordering::Relaxed);
                    self.conns[slot] = Some(conn);
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) => {
                    self.metrics.accept_errors.fetch_add(1, Ordering::Relaxed);
                    eprintln!("finger-serve: accept error (retrying): {e}");
                    std::thread::sleep(accept_backoff(self.accept_streak));
                    self.accept_streak = self.accept_streak.saturating_add(1);
                    return;
                }
            }
        }
    }

    /// Readiness on one connection: pump the framer, route frames, flush.
    fn conn_event(&mut self, slot: usize, errhup: bool, frames: &mut Vec<(u64, String)>) {
        let Some(mut conn) = self.conns.get_mut(slot).and_then(|s| s.take()) else {
            return;
        };
        frames.clear();
        let status = conn.read_frames(frames);
        if status == ReadStatus::FrameTooLong {
            self.metrics.errors.fetch_add(1, Ordering::Relaxed);
            // Best-effort in-band refusal; the framer already marked the
            // connection dead, so write straight to the socket.
            let _ = writeln!(
                conn.stream,
                "{}",
                error_line(0, "frame exceeds the 32 MiB limit")
            );
        }
        if conn.is_dead() || (errhup && frames.is_empty() && !conn.finished()) {
            // Socket error/peer reset with nothing actionable buffered.
            conn.mark_dead();
            frames.clear();
        }
        for (seq, line) in frames.drain(..) {
            self.process_frame(&mut conn, slot, seq, &line);
        }
        conn.flush();
        self.settle(slot, conn);
    }

    /// Route one framed request: queries to the batcher, verbs to the
    /// executor thread, failures straight back onto the connection.
    fn process_frame(&self, conn: &mut Conn, slot: usize, seq: u64, line: &str) {
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        match Request::parse(line) {
            Ok(Request::Query(req)) => {
                // Warm-up gate: a replica binds its listener before it
                // has state, and answers structured warming errors (not
                // connection refusals, not stale results) until caught up.
                if !self.index.is_ready() {
                    conn.complete(seq, &warming_line(req.id));
                    return;
                }
                // Read-your-writes session gate: a query carrying a
                // `min_seq` token refuses to answer from state behind it.
                if let Some(min_seq) = session_min_seq(line) {
                    let applied = self.index.applied_seq();
                    if applied < min_seq {
                        conn.complete(seq, &stale_line(req.id, min_seq, applied));
                        return;
                    }
                }
                if req.vector.len() != self.dim {
                    self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                    let msg = format!("dim mismatch: got {}, want {}", req.vector.len(), self.dim);
                    conn.complete(seq, &error_line(req.id, &msg));
                    return;
                }
                let id = req.id;
                let job = Job {
                    req,
                    submitted: Instant::now(),
                    resp: Responder::Event {
                        slot,
                        gen: conn.gen,
                        seq,
                        done: self.comp_tx.clone(),
                        waker: Arc::clone(&self.waker),
                    },
                };
                match self.batcher.submit(job) {
                    Ok(()) => {}
                    Err(SubmitError::Full) => {
                        self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        conn.complete(seq, &error_line(id, "overloaded"));
                    }
                    Err(SubmitError::Closed) => {
                        conn.complete(seq, &error_line(id, "shutting down"));
                    }
                }
            }
            Ok(req) => {
                let gen = conn.gen;
                if let Err(mpsc::SendError(job)) =
                    self.verbs_tx.send(VerbJob { slot, gen, seq, req })
                {
                    conn.complete(seq, &error_line(job.req.id(), "shutting down"));
                }
            }
            Err(e) => {
                self.metrics.errors.fetch_add(1, Ordering::Relaxed);
                conn.complete(seq, &error_line(request_id_hint(line), &e));
            }
        }
    }

    /// Deliver worker/verb completions to their connections (discarding
    /// any whose slot generation no longer matches — the connection
    /// closed and the slot was recycled).
    fn drain_completions(&mut self) {
        while let Ok(c) = self.comp_rx.try_recv() {
            let Some(mut conn) = self.conns.get_mut(c.slot).and_then(|s| s.take()) else {
                continue;
            };
            if conn.gen != c.gen {
                self.conns[c.slot] = Some(conn);
                continue;
            }
            conn.complete(c.seq, &c.line);
            conn.flush();
            self.settle(c.slot, conn);
        }
    }

    /// Put a connection back in the slab with its poller interest
    /// re-armed, or tear it down if it is finished/dead.
    fn settle(&mut self, slot: usize, mut conn: Conn) {
        if conn.finished() {
            self.close(slot, conn);
            return;
        }
        let desired = (conn.want_read(), conn.want_write());
        if desired != conn.interest {
            if self
                .poller
                .modify(conn.stream.as_raw_fd(), slot as u64, desired.0, desired.1)
                .is_err()
            {
                self.close(slot, conn);
                return;
            }
            conn.interest = desired;
        }
        self.conns[slot] = Some(conn);
    }

    fn close(&mut self, slot: usize, conn: Conn) {
        let _ = self.poller.remove(conn.stream.as_raw_fd());
        conn.recycle(&self.pool);
        self.conns[slot] = None;
        self.free.push(slot);
    }
}

/// Reply line for a non-query verb: mutations, fingerprint, repl_status.
/// Shared by the blocking connection threads and the epoll verb executor
/// so both modes answer identically.
fn verb_reply(index: &ServeIndex, metrics: &Metrics, req: &Request) -> String {
    match req {
        Request::Fingerprint { id } => match index.fingerprint(*id) {
            Ok(info) => info.to_json_line(),
            Err(e) => {
                metrics.errors.fetch_add(1, Ordering::Relaxed);
                error_line(*id, &e)
            }
        },
        Request::ReplStatus { id } => index.repl_status_json(*id),
        other => match index.mutate(other) {
            Ok(resp) => resp.to_json_line(),
            Err(e) => {
                metrics.errors.fetch_add(1, Ordering::Relaxed);
                error_line(other.id(), &e)
            }
        },
    }
}

/// Resolve one dynamic batch. When every request matches the index
/// dimension and asks for the same `k`, the whole batch goes through
/// `AnnIndex::batch_search` — one call, which a `ShardedIndex` scatters
/// across shards in parallel, so batched queries fan out across shards
/// and not just across requests. Mixed `k`s (or mixed dimensions, only
/// reachable via `submit_local`) fall back to per-job searches: sharing
/// one widened search would let a co-batched request's `k` change this
/// request's beam width, making responses depend on batch composition.
fn batch_hits(index: &ServeIndex, batch: &[Job], ctx: &mut SearchContext) -> Vec<Vec<(f32, u32)>> {
    // One read-lock acquisition per dynamic batch: every search in the
    // batch sees the same index snapshot, and concurrent mutation verbs
    // wait at most one batch.
    let ix = rlock(&index.index);
    let dim = ix.dim();
    let uniform = batch.len() > 1
        && batch
            .iter()
            .all(|j| j.req.vector.len() == dim && j.req.k == batch[0].req.k);
    if uniform {
        let mut queries = Matrix::zeros(0, dim);
        for job in batch {
            queries.push_row(&job.req.vector);
        }
        let mut p = index.params.clone();
        p.k = batch[0].req.k;
        return ix
            .batch_search(&queries, &p, ctx)
            .into_iter()
            .map(|res| res.into_iter().map(|n| (n.dist, n.id)).collect())
            .collect();
    }
    batch
        .iter()
        .map(|job| {
            let mut p = index.params.clone();
            p.k = job.req.k;
            ix.search(&job.req.vector, &p, ctx)
                .into_iter()
                .map(|n| (n.dist, n.id))
                .collect()
        })
        .collect()
}

fn handle_conn(
    stream: TcpStream,
    batcher: &Batcher<Job>,
    metrics: &Metrics,
    index: &Arc<ServeIndex>,
) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let dim = index.dim();
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        metrics.requests.fetch_add(1, Ordering::Relaxed);
        let req = match Request::parse(&line) {
            Ok(Request::Query(r)) if r.vector.len() == dim => {
                // Same warm-up and read-your-writes session gates as the
                // epoll mode's `process_frame` — both modes must answer
                // identically.
                if !index.is_ready() {
                    let _ = writeln!(writer, "{}", warming_line(r.id));
                    continue;
                }
                if let Some(min_seq) = session_min_seq(&line) {
                    let applied = index.applied_seq();
                    if applied < min_seq {
                        let _ = writeln!(writer, "{}", stale_line(r.id, min_seq, applied));
                        continue;
                    }
                }
                r
            }
            Ok(Request::Query(r)) => {
                metrics.errors.fetch_add(1, Ordering::Relaxed);
                let _ = writeln!(
                    writer,
                    "{}",
                    error_line(r.id, &format!("dim mismatch: got {}, want {dim}", r.vector.len()))
                );
                continue;
            }
            // Non-query verbs (mutations + introspection) share the reply
            // path with the epoll mode's verb executor.
            Ok(vreq) => {
                let _ = writeln!(writer, "{}", verb_reply(index, metrics, &vreq));
                continue;
            }
            Err(e) => {
                // Malformed frames get a structured error on the same
                // connection — the stream keeps serving.
                metrics.errors.fetch_add(1, Ordering::Relaxed);
                let _ = writeln!(writer, "{}", error_line(request_id_hint(&line), &e));
                continue;
            }
        };
        let (tx, rx) = mpsc::channel();
        let job = Job {
            req,
            submitted: Instant::now(),
            resp: Responder::Channel(tx),
        };
        let id = job.req.id;
        match batcher.submit(job) {
            Ok(()) => match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(resp) => {
                    let _ = writeln!(writer, "{}", resp.to_json_line());
                }
                Err(_) => {
                    metrics.errors.fetch_add(1, Ordering::Relaxed);
                    let _ = writeln!(writer, "{}", error_line(id, "timeout"));
                }
            },
            Err(SubmitError::Full) => {
                metrics.rejected.fetch_add(1, Ordering::Relaxed);
                let _ = writeln!(writer, "{}", error_line(id, "overloaded"));
            }
            Err(SubmitError::Closed) => {
                let _ = writeln!(writer, "{}", error_line(id, "shutting down"));
                break;
            }
        }
    }
}

/// Minimal blocking client for examples and tests.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        // Small JSON frames + request/response turnarounds: Nagle would
        // add up to one delayed-ACK interval (~40ms) per pipelined frame.
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    pub fn query(&mut self, req: &QueryRequest) -> Result<QueryResponse, String> {
        writeln!(self.stream, "{}", req.to_json_line()).map_err(|e| e.to_string())?;
        let mut line = String::new();
        self.reader.read_line(&mut line).map_err(|e| e.to_string())?;
        QueryResponse::parse(line.trim())
    }

    /// Send a mutation verb and parse its acknowledgement.
    pub fn mutate(&mut self, req: &Request) -> Result<MutResponse, String> {
        let line = self.send_raw(&req.to_json_line()).map_err(|e| e.to_string())?;
        MutResponse::parse(line.trim())
    }

    /// Send one raw frame and read one raw response line (protocol tests;
    /// lets a test exercise malformed frames end to end).
    pub fn send_raw(&mut self, frame: &str) -> std::io::Result<String> {
        writeln!(self.stream, "{frame}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::Metric;
    use crate::data::synth::tiny;
    use crate::finger::construct::FingerParams;
    use crate::graph::hnsw::HnswParams;
    use crate::graph::nndescent::NnDescentParams;
    use crate::graph::vamana::VamanaParams;
    use crate::index::impls::{FingerHnswIndex, HnswIndex, IvfPqIndex, NnDescentIndex, VamanaIndex};
    use crate::index::sharded::{ShardSpec, ShardedIndex};
    use crate::quant::ivfpq::IvfPqParams;

    fn test_index() -> Arc<ServeIndex> {
        let ds = tiny(201, 400, 16, Metric::L2);
        let fh = FingerHnswIndex::build(
            Arc::clone(&ds.data),
            HnswParams { m: 8, ef_construction: 40, ..Default::default() },
            FingerParams { rank: 8, ..Default::default() },
        );
        Arc::new(ServeIndex::new(Box::new(fh), 40))
    }

    fn cfg() -> ServerConfig {
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_micros(100),
            max_queue: 256,
            ..Default::default()
        }
    }

    fn threads_cfg() -> ServerConfig {
        ServerConfig { mode: ServeMode::Threads, ..cfg() }
    }

    #[test]
    fn local_submit_roundtrip() {
        let index = test_index();
        let q = index.row(5);
        let server = Server::start(Arc::clone(&index), cfg(), None).unwrap();
        let rx = server
            .submit_local(QueryRequest { id: 1, vector: q, k: 5 })
            .unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.id, 1);
        assert_eq!(resp.hits.len(), 5);
        assert_eq!(resp.hits[0].1, 5, "self-query returns itself first");
        server.shutdown();
    }

    #[test]
    fn tcp_roundtrip_and_errors() {
        let index = test_index();
        let server = Server::start(Arc::clone(&index), cfg(), None).unwrap();
        let mut client = Client::connect(&server.local_addr).unwrap();

        let q = index.row(3);
        let resp = client.query(&QueryRequest { id: 9, vector: q, k: 3 }).unwrap();
        assert_eq!(resp.id, 9);
        assert_eq!(resp.hits[0].1, 3);

        // Dim mismatch -> error response.
        let err = client.query(&QueryRequest { id: 10, vector: vec![1.0, 2.0], k: 3 });
        assert!(err.is_err());

        server.shutdown();
    }

    #[test]
    fn concurrent_clients_all_answered() {
        let index = test_index();
        let server = Arc::new(Server::start(Arc::clone(&index), cfg(), None).unwrap());
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let server = Arc::clone(&server);
            let index = Arc::clone(&index);
            handles.push(std::thread::spawn(move || {
                let mut ok = 0;
                for i in 0..50u64 {
                    let qid = ((t * 50 + i) as usize) % index.len();
                    let rx = server
                        .submit_local(QueryRequest {
                            id: t * 1000 + i,
                            vector: index.row(qid),
                            k: 5,
                        })
                        .unwrap();
                    let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
                    assert_eq!(resp.id, t * 1000 + i);
                    ok += 1;
                }
                ok
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 200);
        let server = Arc::try_unwrap(server).ok().unwrap();
        assert_eq!(server.metrics.responses.load(Ordering::Relaxed), 200);
        server.shutdown();
    }

    /// The worker's batch path (one `batch_search` per dynamic batch, so a
    /// sharded index scatters the whole batch across shards) must return
    /// exactly what each request would get searched alone — responses may
    /// never depend on what a request happened to be batched with.
    #[test]
    fn batch_path_matches_individual_search_on_sharded_index() {
        let ds = tiny(206, 300, 12, Metric::L2);
        let spec = ShardSpec { n_shards: 3, ..Default::default() };
        let sharded = ShardedIndex::build(Arc::clone(&ds.data), &spec, |sub| -> Box<dyn AnnIndex> {
            Box::new(HnswIndex::build(
                sub,
                HnswParams { m: 8, ef_construction: 40, ..Default::default() },
            ))
        });
        let serve = ServeIndex::new(Box::new(sharded), 48);
        let mut ctx = SearchContext::new();
        let jobs = |ks: &[usize]| -> Vec<Job> {
            ks.iter()
                .enumerate()
                .map(|(i, &k)| {
                    let (tx, _rx) = mpsc::channel();
                    Job {
                        req: QueryRequest {
                            id: i as u64,
                            vector: ds.queries.row(i).to_vec(),
                            k,
                        },
                        submitted: Instant::now(),
                        resp: Responder::Channel(tx),
                    }
                })
                .collect()
        };
        // Uniform k exercises the fan-out batch path; mixed k falls back
        // to per-job searches. Either way: identical to searching alone.
        for ks in [vec![5usize; 5], vec![3, 7, 5, 10, 4]] {
            let batch = jobs(&ks);
            let all = batch_hits(&serve, &batch, &mut ctx);
            assert_eq!(all.len(), batch.len());
            for (job, hits) in batch.iter().zip(&all) {
                assert_eq!(hits.len(), job.req.k, "request {}", job.req.id);
                let alone = serve.search(&job.req.vector, job.req.k, &mut ctx);
                assert_eq!(*hits, alone, "request {} (ks {ks:?})", job.req.id);
            }
        }
    }

    /// End-to-end: a sharded index behind the TCP server answers exactly
    /// like any other family.
    #[test]
    fn serves_sharded_index() {
        let ds = tiny(207, 300, 12, Metric::L2);
        let spec = ShardSpec { n_shards: 4, ..Default::default() };
        let sharded = ShardedIndex::build(Arc::clone(&ds.data), &spec, |sub| -> Box<dyn AnnIndex> {
            Box::new(HnswIndex::build(
                sub,
                HnswParams { m: 8, ef_construction: 40, ..Default::default() },
            ))
        });
        let serve = Arc::new(ServeIndex::new(Box::new(sharded), 48));
        let server = Server::start(Arc::clone(&serve), cfg(), None).unwrap();
        let q = serve.row(11);
        let rx = server
            .submit_local(QueryRequest { id: 11, vector: q, k: 5 })
            .unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.hits.len(), 5);
        assert_eq!(resp.hits[0].1, 11, "self-query returns its global id");
        server.shutdown();
    }

    /// Mutation verbs flow over the same TCP connection as searches:
    /// insert → findable, delete → never emitted again, compact → gated,
    /// malformed frames → structured errors with the stream still up.
    #[test]
    fn mutation_verbs_served_alongside_search() {
        let ds = tiny(208, 200, 8, Metric::L2);
        let idx = HnswIndex::build(
            Arc::clone(&ds.data),
            HnswParams { m: 8, ef_construction: 40, ..Default::default() },
        );
        let serve = Arc::new(ServeIndex::new(Box::new(idx), 64));
        let server = Server::start(Arc::clone(&serve), cfg(), None).unwrap();
        let mut client = Client::connect(&server.local_addr).unwrap();

        let v: Vec<f32> = (0..8).map(|i| 50.0 + i as f32).collect();
        let ack = client.mutate(&Request::Insert { id: 1, vector: v.clone() }).unwrap();
        assert_eq!(ack.outcome, MutOutcome::Inserted(200));
        assert_eq!(ack.live, 201);
        let resp = client.query(&QueryRequest { id: 2, vector: v.clone(), k: 1 }).unwrap();
        assert_eq!(resp.hits[0].1, 200, "inserted point is served");

        let ack = client.mutate(&Request::Delete { id: 3, key: 200 }).unwrap();
        assert_eq!(ack.outcome, MutOutcome::Deleted(200));
        assert_eq!(ack.live, 200);
        let resp = client.query(&QueryRequest { id: 4, vector: v, k: 5 }).unwrap();
        assert!(resp.hits.iter().all(|&(_, id)| id != 200), "deleted id emitted");

        // One tombstone in 201 rows is far below the threshold.
        let ack = client.mutate(&Request::Compact { id: 5 }).unwrap();
        assert_eq!(ack.outcome, MutOutcome::Compacted(false));

        // Stale delete and malformed frame: structured errors, and the
        // connection keeps serving afterwards.
        assert!(client.mutate(&Request::Delete { id: 6, key: 200 }).is_err());
        let raw = client.send_raw(r#"{"id":7,"op":"insert"}"#).unwrap();
        assert!(raw.contains("error"), "malformed frame answered in-band: {raw}");
        let resp = client
            .query(&QueryRequest { id: 8, vector: serve.row(0), k: 1 })
            .unwrap();
        assert_eq!(resp.id, 8);
        server.shutdown();
    }

    /// A non-mutable family behind the server answers mutation verbs with
    /// a structured "unsupported" error and keeps serving searches.
    #[test]
    fn non_mutable_family_reports_unsupported() {
        let ds = tiny(209, 100, 8, Metric::L2);
        let idx = VamanaIndex::build(
            Arc::clone(&ds.data),
            VamanaParams { r: 8, ..Default::default() },
        );
        let serve = Arc::new(ServeIndex::new(Box::new(idx), 48));
        let server = Server::start(Arc::clone(&serve), cfg(), None).unwrap();
        let mut client = Client::connect(&server.local_addr).unwrap();
        let err = client
            .mutate(&Request::Insert { id: 1, vector: serve.row(0) })
            .unwrap_err();
        assert!(err.contains("does not support mutation"), "{err}");
        let resp = client.query(&QueryRequest { id: 2, vector: serve.row(0), k: 3 }).unwrap();
        assert_eq!(resp.hits[0].1, 0);
        server.shutdown();
    }

    /// A panic while holding the index lock used to poison it and kill
    /// every later request on every connection. The poison-tolerant
    /// guards keep the server answering.
    #[test]
    fn poisoned_lock_recovers_and_serving_continues() {
        let index = test_index();
        {
            let index = Arc::clone(&index);
            let _ = std::thread::spawn(move || {
                let _guard = index.index.write().unwrap_or_else(|e| e.into_inner());
                panic!("poison the index lock");
            })
            .join();
        }
        let mut ctx = SearchContext::new();
        let hits = index.search(&index.row(0), 3, &mut ctx);
        assert_eq!(hits[0].1, 0, "search survives a poisoned lock");
        let ack = index.mutate(&Request::Delete { id: 1, key: 5 }).unwrap();
        assert_eq!(ack.outcome, MutOutcome::Deleted(5), "mutation survives too");
    }

    /// The replication-era verbs over plain TCP: `set_threshold` applies
    /// and acks, `fingerprint` matches a locally computed hash, and
    /// `repl_status` reports the standalone role.
    #[test]
    fn threshold_fingerprint_and_status_verbs() {
        use crate::router::protocol::FingerprintInfo;
        let ds = tiny(211, 120, 8, Metric::L2);
        let idx = HnswIndex::build(
            Arc::clone(&ds.data),
            HnswParams { m: 8, ef_construction: 40, ..Default::default() },
        );
        let serve = Arc::new(ServeIndex::new(Box::new(idx), 64));
        let server = Server::start(Arc::clone(&serve), cfg(), None).unwrap();
        let mut client = Client::connect(&server.local_addr).unwrap();

        let ack = client.mutate(&Request::SetThreshold { id: 1, frac: 0.5 }).unwrap();
        assert_eq!(ack.outcome, MutOutcome::ThresholdSet(0.5));
        assert_eq!(
            rlock(&serve.index).as_mutable_view().unwrap().compact_threshold(),
            0.5
        );

        let raw = client.send_raw(r#"{"id":2,"op":"fingerprint"}"#).unwrap();
        let info = FingerprintInfo::parse(raw.trim()).unwrap();
        let local = crate::repl::bundle_fingerprint(rlock(&serve.index).as_ref()).unwrap();
        assert_eq!(info.fingerprint, local, "verb matches a locally computed hash");
        assert_eq!(info.live, 120);

        let raw = client.send_raw(r#"{"id":3,"op":"repl_status"}"#).unwrap();
        assert!(raw.contains(r#""role": "standalone""#) || raw.contains(r#""role":"standalone""#),
            "unexpected status line: {raw}");
        server.shutdown();
    }

    /// A replica-role ServeIndex refuses every mutation verb but still
    /// answers reads and introspection.
    #[test]
    fn replica_serve_index_refuses_writes() {
        let ds = tiny(212, 80, 8, Metric::L2);
        let idx = HnswIndex::build(
            Arc::clone(&ds.data),
            HnswParams { m: 8, ef_construction: 40, ..Default::default() },
        );
        let serve = ServeIndex::new(Box::new(idx), 48).as_replica();
        for req in [
            Request::Insert { id: 1, vector: vec![0.0; 8] },
            Request::Delete { id: 2, key: 0 },
            Request::Compact { id: 3 },
            Request::Save { id: 4 },
            Request::SetThreshold { id: 5, frac: 0.5 },
        ] {
            let err = serve.mutate(&req).unwrap_err();
            assert!(err.contains("read-only"), "{err}");
        }
        assert!(serve.fingerprint(6).is_ok(), "introspection still serves");
        assert!(serve.repl_status_json(7).contains("replica"));
        let mut ctx = SearchContext::new();
        assert_eq!(serve.search(&serve.row(0), 1, &mut ctx)[0].1, 0);
    }

    /// SAVE without a WAL is a structured error, not a crash.
    #[test]
    fn save_without_wal_is_a_structured_error() {
        let index = test_index();
        let err = index.mutate(&Request::Save { id: 1 }).unwrap_err();
        assert!(err.contains("--wal-dir"), "{err}");
    }

    /// Full durability loop over TCP: mutations append to the WAL, SAVE
    /// checkpoints mid-flight, and recovery after a "crash" reproduces
    /// the served index byte for byte.
    #[test]
    fn wal_attached_server_logs_saves_and_recovers() {
        use crate::data::persist::save_index;
        use crate::wal::{snapshot_path, FsyncPolicy, Wal};
        let bundle = |index: &dyn AnnIndex, name: &str| -> Vec<u8> {
            let p = std::env::temp_dir()
                .join(format!("finger_srvwal_b_{}_{name}", std::process::id()));
            save_index(&p, index).unwrap();
            let b = std::fs::read(&p).unwrap();
            std::fs::remove_file(&p).ok();
            b
        };

        let ds = tiny(210, 150, 8, Metric::L2);
        let idx = HnswIndex::build(
            Arc::clone(&ds.data),
            HnswParams { m: 8, ef_construction: 40, ..Default::default() },
        );
        let dir = std::env::temp_dir().join(format!("finger_srvwal_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let wal = Arc::new(Wal::bootstrap(&dir, &idx, FsyncPolicy::EveryN(4)).unwrap());
        let serve =
            Arc::new(ServeIndex::new(Box::new(idx), 64).with_wal(Arc::clone(&wal)));
        let server = Server::start(Arc::clone(&serve), cfg(), None).unwrap();
        let mut client = Client::connect(&server.local_addr).unwrap();

        let v: Vec<f32> = (0..8).map(|i| 40.0 + i as f32).collect();
        let ack = client.mutate(&Request::Insert { id: 1, vector: v }).unwrap();
        assert_eq!(ack.outcome, MutOutcome::Inserted(150));
        client.mutate(&Request::Delete { id: 2, key: 3 }).unwrap();

        // SAVE checkpoints through the WAL without a restart.
        let ack = client.mutate(&Request::Save { id: 3 }).unwrap();
        assert_eq!(ack.outcome, MutOutcome::Saved(2));
        assert!(snapshot_path(&dir, 2).exists());

        // One more logged op after the checkpoint, then "crash".
        client.mutate(&Request::Delete { id: 4, key: 7 }).unwrap();
        server.shutdown();

        let (recovered, _wal2, report) = Wal::recover(&dir, FsyncPolicy::Never).unwrap();
        assert_eq!(report.snapshot_seq, 2);
        assert_eq!(report.replayed, 1, "only the post-checkpoint op replays");
        assert!(report.corruption.is_none(), "{report:?}");
        let served = bundle(rlock(&serve.index).as_ref(), "served");
        assert_eq!(
            bundle(recovered.as_ref(), "recovered"),
            served,
            "recovered bundle must byte-match the served index"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The families the old two-variant `IndexKind` enum could not serve
    /// now run behind the same server unchanged.
    #[test]
    fn serves_every_index_family() {
        let ds = tiny(205, 300, 12, Metric::L2);
        let indexes: Vec<Box<dyn AnnIndex>> = vec![
            Box::new(VamanaIndex::build(
                Arc::clone(&ds.data),
                VamanaParams { r: 12, ..Default::default() },
            )),
            Box::new(NnDescentIndex::build(
                Arc::clone(&ds.data),
                NnDescentParams { degree: 12, ..Default::default() },
            )),
            Box::new(IvfPqIndex::build(
                Arc::clone(&ds.data),
                IvfPqParams { n_list: 8, ..Default::default() },
            )),
        ];
        for idx in indexes {
            let name = idx.name();
            let serve = Arc::new(ServeIndex::new(idx, 48));
            let server = Server::start(Arc::clone(&serve), cfg(), None).unwrap();
            let q = serve.row(7);
            let rx = server
                .submit_local(QueryRequest { id: 7, vector: q, k: 5 })
                .unwrap();
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.hits.len(), 5, "{name}");
            assert_eq!(resp.hits[0].1, 7, "{name}: self-query top hit");
            server.shutdown();
        }
    }

    /// Serializes the threads-mode tests: the spawn-failure injection is
    /// a process-global counter, so another concurrently accepting
    /// threads-mode server could consume it.
    static THREADS_MODE_LOCK: Mutex<()> = Mutex::new(());

    /// The portable fallback keeps serving queries and mutations.
    #[test]
    fn threads_mode_still_serves() {
        let _serial = mlock(&THREADS_MODE_LOCK);
        let ds = tiny(220, 150, 8, Metric::L2);
        let idx = HnswIndex::build(
            Arc::clone(&ds.data),
            HnswParams { m: 8, ef_construction: 40, ..Default::default() },
        );
        let serve = Arc::new(ServeIndex::new(Box::new(idx), 64));
        let server = Server::start(Arc::clone(&serve), threads_cfg(), None).unwrap();
        let mut client = Client::connect(&server.local_addr).unwrap();
        let resp = client.query(&QueryRequest { id: 1, vector: serve.row(4), k: 3 }).unwrap();
        assert_eq!(resp.hits[0].1, 4);
        let v: Vec<f32> = (0..8).map(|i| 90.0 + i as f32).collect();
        let ack = client.mutate(&Request::Insert { id: 2, vector: v }).unwrap();
        assert_eq!(ack.outcome, MutOutcome::Inserted(150));
        server.shutdown();
    }

    /// Regression (threads fallback): a connection-thread spawn failure
    /// used to be swallowed with `.ok()` — the client was dropped with no
    /// response and no metric. It must get an in-band structured error,
    /// the failure must be counted, and the server must keep accepting.
    #[test]
    fn spawn_failure_is_counted_and_reported_in_band() {
        let _serial = mlock(&THREADS_MODE_LOCK);
        let index = test_index();
        let server = Server::start(Arc::clone(&index), threads_cfg(), None).unwrap();

        INJECT_SPAWN_FAILURES.store(1, Ordering::SeqCst);
        let refused = TcpStream::connect(server.local_addr).unwrap();
        let mut line = String::new();
        BufReader::new(&refused)
            .read_line(&mut line)
            .expect("refusal line arrives before close");
        assert!(line.contains("error"), "structured refusal, got: {line}");
        assert!(line.contains("cannot serve connection"), "got: {line}");

        // The accept loop survived and the next client is served normally.
        let mut client = Client::connect(&server.local_addr).unwrap();
        let resp = client.query(&QueryRequest { id: 1, vector: index.row(2), k: 2 }).unwrap();
        assert_eq!(resp.hits[0].1, 2);
        assert_eq!(server.metrics.spawn_failures.load(Ordering::Relaxed), 1);
        assert_eq!(INJECT_SPAWN_FAILURES.load(Ordering::SeqCst), 0);
        server.shutdown();
    }

    /// Regression: query-plane sockets never set TCP_NODELAY, so Nagle
    /// could add ~40ms to small pipelined frames.
    #[test]
    fn client_connection_disables_nagle() {
        let index = test_index();
        let server = Server::start(Arc::clone(&index), cfg(), None).unwrap();
        let client = Client::connect(&server.local_addr).unwrap();
        assert!(client.stream.nodelay().unwrap(), "Client::connect must set TCP_NODELAY");
        server.shutdown();
    }

    #[test]
    fn serve_mode_parsing_and_default() {
        assert_eq!(ServeMode::parse("threads").unwrap(), ServeMode::Threads);
        assert_eq!(ServeMode::parse("epoll").unwrap(), ServeMode::Epoll);
        assert!(ServeMode::parse("tokio").is_err());
        if poll::SUPPORTED {
            assert_eq!(ServeMode::default(), ServeMode::Epoll, "epoll is the Linux default");
        } else {
            assert_eq!(ServeMode::default(), ServeMode::Threads);
        }
        assert_eq!(ServeMode::Threads.name(), "threads");
        assert_eq!(ServeMode::Epoll.name(), "epoll");
    }

    /// The accept-error backoff grows exponentially and is capped — the
    /// loop never sleeps unboundedly and never dies.
    #[test]
    fn accept_backoff_grows_and_caps() {
        assert_eq!(accept_backoff(0), Duration::from_millis(1));
        assert_eq!(accept_backoff(1), Duration::from_millis(2));
        assert_eq!(accept_backoff(5), Duration::from_millis(32));
        assert_eq!(accept_backoff(6), Duration::from_millis(50));
        assert_eq!(accept_backoff(1_000_000), Duration::from_millis(50));
    }

    /// Pipelining under the event loop: many frames written in one
    /// segment come back as exactly one response per frame, in request
    /// order, with a malformed frame answered in-band at its position.
    #[test]
    fn epoll_pipelined_frames_answered_in_order() {
        if !poll::SUPPORTED {
            return;
        }
        let index = test_index();
        let server = Server::start(Arc::clone(&index), cfg(), None).unwrap();
        let mut stream = TcpStream::connect(server.local_addr).unwrap();
        stream.set_nodelay(true).unwrap();

        let mut blob = String::new();
        for i in 0..8u64 {
            if i == 3 {
                blob.push_str("{not json\n");
            } else {
                let req = QueryRequest { id: i, vector: index.row(i as usize), k: 2 };
                blob.push_str(&req.to_json_line());
                blob.push('\n');
            }
        }
        stream.write_all(blob.as_bytes()).unwrap();

        let mut reader = BufReader::new(&stream);
        for i in 0..8u64 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            if i == 3 {
                assert!(line.contains("error"), "frame 3 is the malformed one: {line}");
            } else {
                let resp = QueryResponse::parse(line.trim()).unwrap();
                assert_eq!(resp.id, i, "responses arrive in request order");
                assert_eq!(resp.hits[0].1, i as u32, "self-query top hit");
            }
        }
        server.shutdown();
    }
}
