//! Wire protocol: JSON lines over TCP.
//!
//! Request:  {"id": 7, "vector": [f32...], "k": 10}
//! Response: {"id": 7, "ids": [u32...], "dists": [f32...],
//!            "latency_us": 123, "exact": true}
//! Error:    {"id": 7, "error": "..."}

use crate::core::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct QueryRequest {
    pub id: u64,
    pub vector: Vec<f32>,
    pub k: usize,
}

#[derive(Clone, Debug, PartialEq)]
pub struct QueryResponse {
    pub id: u64,
    pub hits: Vec<(f32, u32)>,
    pub latency_us: u64,
}

impl QueryRequest {
    pub fn parse(line: &str) -> Result<QueryRequest, String> {
        let v = Json::parse(line).map_err(|e| e.to_string())?;
        let id = v
            .get("id")
            .and_then(|x| x.as_f64())
            .ok_or("missing id")? as u64;
        let vector: Vec<f32> = v
            .get("vector")
            .and_then(|x| x.as_arr())
            .ok_or("missing vector")?
            .iter()
            .map(|x| x.as_f64().map(|f| f as f32).ok_or("non-numeric vector entry"))
            .collect::<Result<_, _>>()?;
        if vector.is_empty() {
            return Err("empty vector".into());
        }
        let k = v.get("k").and_then(|x| x.as_usize()).unwrap_or(10);
        if k == 0 {
            return Err("k must be positive".into());
        }
        Ok(QueryRequest { id, vector, k })
    }

    pub fn to_json_line(&self) -> String {
        let vec = Json::Arr(self.vector.iter().map(|&x| Json::Num(x as f64)).collect());
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("vector", vec),
            ("k", Json::Num(self.k as f64)),
        ])
        .to_string()
    }
}

impl QueryResponse {
    pub fn to_json_line(&self) -> String {
        let ids = Json::Arr(self.hits.iter().map(|&(_, id)| Json::Num(id as f64)).collect());
        let dists = Json::Arr(self.hits.iter().map(|&(d, _)| Json::Num(d as f64)).collect());
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("ids", ids),
            ("dists", dists),
            ("latency_us", Json::Num(self.latency_us as f64)),
        ])
        .to_string()
    }

    pub fn parse(line: &str) -> Result<QueryResponse, String> {
        let v = Json::parse(line).map_err(|e| e.to_string())?;
        if let Some(err) = v.get("error").and_then(|e| e.as_str()) {
            return Err(err.to_string());
        }
        let id = v.get("id").and_then(|x| x.as_f64()).ok_or("missing id")? as u64;
        let ids = v.get("ids").and_then(|x| x.as_arr()).ok_or("missing ids")?;
        let dists = v.get("dists").and_then(|x| x.as_arr()).ok_or("missing dists")?;
        if ids.len() != dists.len() {
            return Err("ids/dists length mismatch".into());
        }
        let hits = ids
            .iter()
            .zip(dists)
            .map(|(i, d)| {
                Ok((
                    d.as_f64().ok_or("bad dist")? as f32,
                    i.as_f64().ok_or("bad id")? as u32,
                ))
            })
            .collect::<Result<_, String>>()?;
        let latency_us = v.get("latency_us").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64;
        Ok(QueryResponse { id, hits, latency_us })
    }
}

pub fn error_line(id: u64, msg: &str) -> String {
    Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("error", Json::str(msg)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = QueryRequest {
            id: 42,
            vector: vec![1.5, -2.0, 0.25],
            k: 5,
        };
        let back = QueryRequest::parse(&r.to_json_line()).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn response_roundtrip() {
        let r = QueryResponse {
            id: 7,
            hits: vec![(0.5, 3), (1.25, 9)],
            latency_us: 88,
        };
        let back = QueryResponse::parse(&r.to_json_line()).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn rejects_malformed() {
        assert!(QueryRequest::parse("{}").is_err());
        assert!(QueryRequest::parse(r#"{"id":1,"vector":[]}"#).is_err());
        assert!(QueryRequest::parse(r#"{"id":1,"vector":[1],"k":0}"#).is_err());
        assert!(QueryRequest::parse("not json").is_err());
    }

    #[test]
    fn default_k_is_10() {
        let r = QueryRequest::parse(r#"{"id":1,"vector":[1.0,2.0]}"#).unwrap();
        assert_eq!(r.k, 10);
    }

    #[test]
    fn error_line_parses_as_error() {
        let line = error_line(3, "boom");
        assert_eq!(QueryResponse::parse(&line), Err("boom".to_string()));
    }
}
