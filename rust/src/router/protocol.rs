//! Wire protocol: JSON lines over TCP.
//!
//! Search (the default when `op` is absent — wire-compatible with every
//! older client):
//!   Request:  {"id": 7, "vector": [f32...], "k": 10}
//!   Response: {"id": 7, "ids": [u32...], "dists": [f32...],
//!              "latency_us": 123}
//!
//! `k` is required and must be a positive integer: a request that omits
//! it (or sends 0, a fraction, or a non-number) is answered with a
//! structured error rather than silently searched with a default — a
//! malformed client must never mistake 10 arbitrary hits for its answer.
//!
//! Mutation verbs (served concurrently with search batches; the server
//! takes the index's write lock per mutation):
//!   {"id": 8, "op": "insert", "vector": [f32...]}
//!       -> {"id": 8, "inserted": <assigned id>, "live": <live count>}
//!   {"id": 9, "op": "delete", "key": 42}
//!       -> {"id": 9, "deleted": 42, "live": ...}
//!   {"id": 10, "op": "compact"}
//!       -> {"id": 10, "compacted": true|false, "live": ...}
//!   {"id": 11, "op": "save"}
//!       -> {"id": 11, "saved": <checkpoint seq>, "live": ...}
//!   {"id": 12, "op": "set_threshold", "frac": 0.25}
//!       -> {"id": 12, "threshold": 0.25, "live": ...}
//!
//! `save` checkpoints the serving index through the WAL (fresh snapshot +
//! log rotation) without a restart; it requires the server to be running
//! with `--wal-dir`. `set_threshold` retunes the compaction gate as a
//! logged, replicated op (so replay and replicas gate identically).
//!
//! Read-only introspection verbs (allowed on replicas, never logged):
//!   {"id": 13, "op": "fingerprint"}
//!       -> {"id": 13, "fingerprint": "<hex u64>", "seq": N, "live": ...}
//!   {"id": 14, "op": "repl_status"}
//!       -> {"id": 14, "role": "primary|replica|standalone", "seq": N,
//!           ...role-specific fields}
//!
//! `fingerprint` hashes the index's persisted-bundle bytes (FNV-1a 64);
//! determinism makes equal fingerprints mean byte-identical state, so
//! comparing them across a primary and its replicas is the divergence
//! check. The hash travels as a hex string because JSON numbers are f64.
//!
//! Every failure — malformed frame, unknown verb, unsupported family,
//! stale id — is a structured `{"id": N, "error": "..."}` line on the
//! same connection, never a disconnect.

use crate::core::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct QueryRequest {
    pub id: u64,
    pub vector: Vec<f32>,
    pub k: usize,
}

#[derive(Clone, Debug, PartialEq)]
pub struct QueryResponse {
    pub id: u64,
    pub hits: Vec<(f32, u32)>,
    pub latency_us: u64,
}

impl QueryRequest {
    pub fn parse(line: &str) -> Result<QueryRequest, String> {
        let v = Json::parse(line).map_err(|e| e.to_string())?;
        QueryRequest::from_json(&v)
    }

    /// Build from an already-parsed value (the framed [`Request::parse`]
    /// path uses this so a search line is JSON-parsed exactly once).
    pub fn from_json(v: &Json) -> Result<QueryRequest, String> {
        let id = v
            .get("id")
            .and_then(|x| x.as_f64())
            .ok_or("missing id")? as u64;
        let vector: Vec<f32> = v
            .get("vector")
            .and_then(|x| x.as_arr())
            .ok_or("missing vector")?
            .iter()
            .map(|x| x.as_f64().map(|f| f as f32).ok_or("non-numeric vector entry"))
            .collect::<Result<_, _>>()?;
        if vector.is_empty() {
            return Err("empty vector".into());
        }
        // `k` is mandatory and validated strictly: `as_usize` would
        // truncate 2.5 to 2 and a missing field used to default to 10 —
        // both silently served the wrong answer instead of an error.
        let k = match v.get("k") {
            None => return Err("missing k (must be a positive integer)".into()),
            Some(x) => {
                let f = x.as_f64().ok_or("k must be a positive integer")?;
                if !f.is_finite() || f.fract() != 0.0 || !(1.0..=u32::MAX as f64).contains(&f) {
                    return Err("k must be a positive integer".into());
                }
                f as usize
            }
        };
        Ok(QueryRequest { id, vector, k })
    }

    pub fn to_json_line(&self) -> String {
        let vec = Json::Arr(self.vector.iter().map(|&x| Json::Num(x as f64)).collect());
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("vector", vec),
            ("k", Json::Num(self.k as f64)),
        ])
        .to_string()
    }
}

impl QueryResponse {
    pub fn to_json_line(&self) -> String {
        let ids = Json::Arr(self.hits.iter().map(|&(_, id)| Json::Num(id as f64)).collect());
        let dists = Json::Arr(self.hits.iter().map(|&(d, _)| Json::Num(d as f64)).collect());
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("ids", ids),
            ("dists", dists),
            ("latency_us", Json::Num(self.latency_us as f64)),
        ])
        .to_string()
    }

    pub fn parse(line: &str) -> Result<QueryResponse, String> {
        let v = Json::parse(line).map_err(|e| e.to_string())?;
        if let Some(err) = v.get("error").and_then(|e| e.as_str()) {
            return Err(err.to_string());
        }
        let id = v.get("id").and_then(|x| x.as_f64()).ok_or("missing id")? as u64;
        let ids = v.get("ids").and_then(|x| x.as_arr()).ok_or("missing ids")?;
        let dists = v.get("dists").and_then(|x| x.as_arr()).ok_or("missing dists")?;
        if ids.len() != dists.len() {
            return Err("ids/dists length mismatch".into());
        }
        let hits = ids
            .iter()
            .zip(dists)
            .map(|(i, d)| {
                Ok((
                    d.as_f64().ok_or("bad dist")? as f32,
                    i.as_f64().ok_or("bad id")? as u32,
                ))
            })
            .collect::<Result<_, String>>()?;
        let latency_us = v.get("latency_us").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64;
        Ok(QueryResponse { id, hits, latency_us })
    }
}

pub fn error_line(id: u64, msg: &str) -> String {
    Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("error", Json::str(msg)),
    ])
    .to_string()
}

/// Structured answer for a replica that has not finished its first
/// catch-up: health probes see a live listener and a parseable state
/// instead of connection-refused. Parses as an error (clients retry),
/// but carries a machine-readable `state` field.
pub fn warming_line(id: u64) -> String {
    Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("state", Json::str("warming")),
        ("error", Json::str("warming: replica has not caught up yet")),
    ])
    .to_string()
}

/// Structured rejection for a read-your-writes session query landing on
/// a replica still behind the session's write position. The client's
/// pool treats it as a failed node and tries the next one.
pub fn stale_line(id: u64, min_seq: u64, applied: u64) -> String {
    Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("state", Json::str("stale")),
        (
            "error",
            Json::str(&format!(
                "stale-replica: serving seq {applied} is behind session min_seq {min_seq}"
            )),
        ),
    ])
    .to_string()
}

/// Extract the optional `min_seq` session token from a raw query line.
/// The substring guard keeps the common (token-less) path from paying a
/// second JSON parse.
pub fn session_min_seq(line: &str) -> Option<u64> {
    if !line.contains("\"min_seq\"") {
        return None;
    }
    Json::parse(line)
        .ok()?
        .get("min_seq")?
        .as_f64()
        .filter(|f| f.is_finite() && *f >= 0.0)
        .map(|f| f as u64)
}

/// Best-effort frame id for error reporting on a line that failed
/// [`Request::parse`]: if the line is still valid JSON with a numeric
/// `id` (e.g. a well-formed frame with a bad `k`), the error can be
/// correlated to the request that caused it; otherwise 0.
pub fn request_id_hint(line: &str) -> u64 {
    Json::parse(line)
        .ok()
        .and_then(|v| v.get("id").and_then(|x| x.as_f64()))
        .filter(|f| f.is_finite() && *f >= 0.0)
        .map_or(0, |f| f as u64)
}

/// One parsed request frame: a search or one of the mutation verbs.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Query(QueryRequest),
    Insert { id: u64, vector: Vec<f32> },
    Delete { id: u64, key: u32 },
    Compact { id: u64 },
    Save { id: u64 },
    /// Retune the compaction gate — logged and replicated like any
    /// mutation, so replay/replica compaction gates identically.
    SetThreshold { id: u64, frac: f64 },
    /// Hash of the persisted-bundle bytes (read-only, replica-safe).
    Fingerprint { id: u64 },
    /// Replication role/progress introspection (read-only).
    ReplStatus { id: u64 },
}

impl Request {
    /// Parse a frame, dispatching on the optional `op` field (absent or
    /// `"search"` = query, for wire compatibility with older clients).
    pub fn parse(line: &str) -> Result<Request, String> {
        let v = Json::parse(line).map_err(|e| e.to_string())?;
        let op = v.get("op").and_then(|x| x.as_str()).unwrap_or("search");
        match op {
            "search" => QueryRequest::from_json(&v).map(Request::Query),
            "insert" => {
                let id = v.get("id").and_then(|x| x.as_f64()).ok_or("missing id")? as u64;
                let vector: Vec<f32> = v
                    .get("vector")
                    .and_then(|x| x.as_arr())
                    .ok_or("insert requires a vector")?
                    .iter()
                    .map(|x| x.as_f64().map(|f| f as f32).ok_or("non-numeric vector entry"))
                    .collect::<Result<_, _>>()?;
                if vector.is_empty() {
                    return Err("empty vector".into());
                }
                Ok(Request::Insert { id, vector })
            }
            "delete" => {
                let id = v.get("id").and_then(|x| x.as_f64()).ok_or("missing id")? as u64;
                let key = v
                    .get("key")
                    .and_then(|x| x.as_f64())
                    .ok_or("delete requires a key")?;
                if !(0.0..=u32::MAX as f64).contains(&key) || key.fract() != 0.0 {
                    return Err("key must be a u32".into());
                }
                Ok(Request::Delete { id, key: key as u32 })
            }
            "compact" => {
                let id = v.get("id").and_then(|x| x.as_f64()).ok_or("missing id")? as u64;
                Ok(Request::Compact { id })
            }
            "save" => {
                let id = v.get("id").and_then(|x| x.as_f64()).ok_or("missing id")? as u64;
                Ok(Request::Save { id })
            }
            "set_threshold" => {
                let id = v.get("id").and_then(|x| x.as_f64()).ok_or("missing id")? as u64;
                let frac = v
                    .get("frac")
                    .and_then(|x| x.as_f64())
                    .ok_or("set_threshold requires a frac")?;
                if !frac.is_finite() || !(0.0..=1.0).contains(&frac) || frac == 0.0 {
                    return Err("frac must be in (0, 1]".into());
                }
                Ok(Request::SetThreshold { id, frac })
            }
            "fingerprint" => {
                let id = v.get("id").and_then(|x| x.as_f64()).ok_or("missing id")? as u64;
                Ok(Request::Fingerprint { id })
            }
            "repl_status" => {
                let id = v.get("id").and_then(|x| x.as_f64()).ok_or("missing id")? as u64;
                Ok(Request::ReplStatus { id })
            }
            other => Err(format!("unknown op '{other}'")),
        }
    }

    /// Frame id for error reporting (0 when unparseable).
    pub fn id(&self) -> u64 {
        match self {
            Request::Query(q) => q.id,
            Request::Insert { id, .. }
            | Request::Delete { id, .. }
            | Request::Compact { id }
            | Request::Save { id }
            | Request::SetThreshold { id, .. }
            | Request::Fingerprint { id }
            | Request::ReplStatus { id } => *id,
        }
    }

    pub fn to_json_line(&self) -> String {
        match self {
            Request::Query(q) => q.to_json_line(),
            Request::Insert { id, vector } => Json::obj(vec![
                ("id", Json::Num(*id as f64)),
                ("op", Json::str("insert")),
                (
                    "vector",
                    Json::Arr(vector.iter().map(|&x| Json::Num(x as f64)).collect()),
                ),
            ])
            .to_string(),
            Request::Delete { id, key } => Json::obj(vec![
                ("id", Json::Num(*id as f64)),
                ("op", Json::str("delete")),
                ("key", Json::Num(*key as f64)),
            ])
            .to_string(),
            Request::Compact { id } => Json::obj(vec![
                ("id", Json::Num(*id as f64)),
                ("op", Json::str("compact")),
            ])
            .to_string(),
            Request::Save { id } => Json::obj(vec![
                ("id", Json::Num(*id as f64)),
                ("op", Json::str("save")),
            ])
            .to_string(),
            Request::SetThreshold { id, frac } => Json::obj(vec![
                ("id", Json::Num(*id as f64)),
                ("op", Json::str("set_threshold")),
                ("frac", Json::Num(*frac)),
            ])
            .to_string(),
            Request::Fingerprint { id } => Json::obj(vec![
                ("id", Json::Num(*id as f64)),
                ("op", Json::str("fingerprint")),
            ])
            .to_string(),
            Request::ReplStatus { id } => Json::obj(vec![
                ("id", Json::Num(*id as f64)),
                ("op", Json::str("repl_status")),
            ])
            .to_string(),
        }
    }
}

/// What a mutation verb did. (`PartialEq` only: `ThresholdSet` carries
/// an `f64`.)
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MutOutcome {
    Inserted(u32),
    Deleted(u32),
    Compacted(bool),
    /// Checkpoint written; carries the new snapshot sequence.
    Saved(u64),
    /// Compaction gate retuned; carries the new threshold.
    ThresholdSet(f64),
}

/// Acknowledgement for a mutation verb, with the post-op live count and
/// the op's log sequence (0 when the server runs without a WAL). The
/// sequence is the read-your-writes session token: feed it to
/// `ReadPool::note_write` and later queries in the session carry it as
/// `min_seq`.
#[derive(Clone, Debug, PartialEq)]
pub struct MutResponse {
    pub id: u64,
    pub outcome: MutOutcome,
    pub live: u64,
    pub seq: u64,
}

impl MutResponse {
    pub fn to_json_line(&self) -> String {
        let (key, val) = match self.outcome {
            MutOutcome::Inserted(id) => ("inserted", Json::Num(id as f64)),
            MutOutcome::Deleted(id) => ("deleted", Json::Num(id as f64)),
            MutOutcome::Compacted(did) => ("compacted", Json::Bool(did)),
            MutOutcome::Saved(seq) => ("saved", Json::Num(seq as f64)),
            MutOutcome::ThresholdSet(frac) => ("threshold", Json::Num(frac)),
        };
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            (key, val),
            ("live", Json::Num(self.live as f64)),
            ("seq", Json::Num(self.seq as f64)),
        ])
        .to_string()
    }

    pub fn parse(line: &str) -> Result<MutResponse, String> {
        let v = Json::parse(line).map_err(|e| e.to_string())?;
        if let Some(err) = v.get("error").and_then(|e| e.as_str()) {
            return Err(err.to_string());
        }
        let id = v.get("id").and_then(|x| x.as_f64()).ok_or("missing id")? as u64;
        let live = v.get("live").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64;
        // Additive field: acks from older servers simply have no seq.
        let seq = v.get("seq").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64;
        let outcome = if let Some(x) = v.get("inserted").and_then(|x| x.as_f64()) {
            MutOutcome::Inserted(x as u32)
        } else if let Some(x) = v.get("deleted").and_then(|x| x.as_f64()) {
            MutOutcome::Deleted(x as u32)
        } else if let Some(b) = v.get("compacted").and_then(|x| x.as_bool()) {
            MutOutcome::Compacted(b)
        } else if let Some(x) = v.get("saved").and_then(|x| x.as_f64()) {
            MutOutcome::Saved(x as u64)
        } else if let Some(x) = v.get("threshold").and_then(|x| x.as_f64()) {
            MutOutcome::ThresholdSet(x)
        } else {
            return Err("not a mutation acknowledgement".into());
        };
        Ok(MutResponse { id, outcome, live, seq })
    }
}

/// Answer to the `fingerprint` verb. The 64-bit hash is carried as a
/// hex string: JSON numbers are f64 and cannot hold a u64 exactly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FingerprintInfo {
    pub id: u64,
    /// FNV-1a 64 over the persisted-bundle bytes.
    pub fingerprint: u64,
    /// Last op sequence applied when the hash was taken (0 = no WAL).
    pub seq: u64,
    pub live: u64,
}

impl FingerprintInfo {
    pub fn to_json_line(&self) -> String {
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("fingerprint", Json::str(&format!("{:016x}", self.fingerprint))),
            ("seq", Json::Num(self.seq as f64)),
            ("live", Json::Num(self.live as f64)),
        ])
        .to_string()
    }

    pub fn parse(line: &str) -> Result<FingerprintInfo, String> {
        let v = Json::parse(line).map_err(|e| e.to_string())?;
        if let Some(err) = v.get("error").and_then(|e| e.as_str()) {
            return Err(err.to_string());
        }
        let id = v.get("id").and_then(|x| x.as_f64()).ok_or("missing id")? as u64;
        let fp = v
            .get("fingerprint")
            .and_then(|x| x.as_str())
            .ok_or("missing fingerprint")?;
        let fingerprint =
            u64::from_str_radix(fp, 16).map_err(|_| format!("bad fingerprint hex '{fp}'"))?;
        let seq = v.get("seq").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64;
        let live = v.get("live").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64;
        Ok(FingerprintInfo { id, fingerprint, seq, live })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = QueryRequest {
            id: 42,
            vector: vec![1.5, -2.0, 0.25],
            k: 5,
        };
        let back = QueryRequest::parse(&r.to_json_line()).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn response_roundtrip() {
        let r = QueryResponse {
            id: 7,
            hits: vec![(0.5, 3), (1.25, 9)],
            latency_us: 88,
        };
        let back = QueryResponse::parse(&r.to_json_line()).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn rejects_malformed() {
        assert!(QueryRequest::parse("{}").is_err());
        assert!(QueryRequest::parse(r#"{"id":1,"vector":[]}"#).is_err());
        assert!(QueryRequest::parse(r#"{"id":1,"vector":[1],"k":0}"#).is_err());
        assert!(QueryRequest::parse("not json").is_err());
    }

    /// Regression: `k` used to default to 10 when missing and truncate
    /// when fractional — a malformed request got 10 (or the wrong number
    /// of) hits instead of an error.
    #[test]
    fn missing_zero_or_non_integer_k_is_rejected() {
        for frame in [
            r#"{"id":1,"vector":[1.0,2.0]}"#,
            r#"{"id":1,"vector":[1.0,2.0],"k":0}"#,
            r#"{"id":1,"vector":[1.0,2.0],"k":2.5}"#,
            r#"{"id":1,"vector":[1.0,2.0],"k":-3}"#,
            r#"{"id":1,"vector":[1.0,2.0],"k":"ten"}"#,
            r#"{"id":1,"vector":[1.0,2.0],"k":1e300}"#,
        ] {
            let err = QueryRequest::parse(frame).unwrap_err();
            assert!(err.contains('k'), "{frame} -> {err}");
        }
        // Integral-valued floats are fine (all JSON numbers are f64).
        let r = QueryRequest::parse(r#"{"id":1,"vector":[1.0,2.0],"k":7.0}"#).unwrap();
        assert_eq!(r.k, 7);
    }

    #[test]
    fn request_id_hint_recovers_ids_when_possible() {
        assert_eq!(request_id_hint(r#"{"id":42,"vector":[1.0],"k":0}"#), 42);
        assert_eq!(request_id_hint("{garbage"), 0);
        assert_eq!(request_id_hint(r#"{"vector":[1.0]}"#), 0);
        assert_eq!(request_id_hint(r#"{"id":"seven"}"#), 0);
        assert_eq!(request_id_hint(r#"{"id":-4}"#), 0);
    }

    #[test]
    fn error_line_parses_as_error() {
        let line = error_line(3, "boom");
        assert_eq!(QueryResponse::parse(&line), Err("boom".to_string()));
    }

    #[test]
    fn mutation_request_roundtrips() {
        let frames = [
            Request::Insert { id: 1, vector: vec![0.5, -1.0] },
            Request::Delete { id: 2, key: 77 },
            Request::Compact { id: 3 },
            Request::Query(QueryRequest { id: 4, vector: vec![1.0], k: 2 }),
            Request::Save { id: 5 },
            Request::SetThreshold { id: 6, frac: 0.25 },
            Request::Fingerprint { id: 7 },
            Request::ReplStatus { id: 8 },
        ];
        for f in frames {
            let back = Request::parse(&f.to_json_line()).unwrap();
            assert_eq!(f, back);
        }
    }

    #[test]
    fn plain_search_frames_stay_wire_compatible() {
        // No "op" field = search, exactly as older clients send it.
        let r = Request::parse(r#"{"id":5,"vector":[1.0,2.0],"k":3}"#).unwrap();
        assert_eq!(
            r,
            Request::Query(QueryRequest { id: 5, vector: vec![1.0, 2.0], k: 3 })
        );
        assert_eq!(r.id(), 5);
    }

    #[test]
    fn malformed_mutation_frames_are_structured_errors() {
        assert!(Request::parse(r#"{"id":1,"op":"insert"}"#).is_err());
        assert!(Request::parse(r#"{"id":1,"op":"insert","vector":[]}"#).is_err());
        assert!(Request::parse(r#"{"id":1,"op":"delete"}"#).is_err());
        assert!(Request::parse(r#"{"id":1,"op":"delete","key":-3}"#).is_err());
        assert!(Request::parse(r#"{"id":1,"op":"delete","key":1.5}"#).is_err());
        assert!(Request::parse(r#"{"id":1,"op":"frobnicate"}"#).is_err());
        assert!(Request::parse(r#"{"op":"compact"}"#).is_err(), "compact needs an id");
        assert!(Request::parse(r#"{"op":"save"}"#).is_err(), "save needs an id");
        assert!(Request::parse(r#"{"id":1,"op":"set_threshold"}"#).is_err());
        assert!(Request::parse(r#"{"id":1,"op":"set_threshold","frac":0.0}"#).is_err());
        assert!(Request::parse(r#"{"id":1,"op":"set_threshold","frac":1.5}"#).is_err());
        assert!(Request::parse(r#"{"id":1,"op":"set_threshold","frac":-0.5}"#).is_err());
        assert!(Request::parse(r#"{"op":"fingerprint"}"#).is_err(), "fingerprint needs an id");
    }

    #[test]
    fn mutation_response_roundtrips() {
        for outcome in [
            MutOutcome::Inserted(9),
            MutOutcome::Deleted(4),
            MutOutcome::Compacted(true),
            MutOutcome::Compacted(false),
            MutOutcome::Saved(12),
            MutOutcome::ThresholdSet(0.25),
        ] {
            let resp = MutResponse { id: 11, outcome, live: 100, seq: 17 };
            let back = MutResponse::parse(&resp.to_json_line()).unwrap();
            assert_eq!(resp, back);
        }
        let line = error_line(3, "nope");
        assert_eq!(MutResponse::parse(&line), Err("nope".to_string()));
        // Acks from servers that predate the seq field still parse.
        let legacy = r#"{"id": 1, "inserted": 5, "live": 9}"#;
        assert_eq!(MutResponse::parse(legacy).unwrap().seq, 0);
    }

    #[test]
    fn warming_and_stale_lines_are_structured_errors_with_state() {
        let w = warming_line(4);
        let err = QueryResponse::parse(&w).unwrap_err();
        assert!(err.contains("warming"), "{err}");
        let v = Json::parse(&w).unwrap();
        assert_eq!(v.get("state").and_then(|s| s.as_str()), Some("warming"));

        let s = stale_line(5, 12, 9);
        let err = QueryResponse::parse(&s).unwrap_err();
        assert!(err.contains("stale-replica"), "{err}");
        assert!(err.contains("12") && err.contains('9'), "{err}");
        let v = Json::parse(&s).unwrap();
        assert_eq!(v.get("state").and_then(|x| x.as_str()), Some("stale"));
    }

    #[test]
    fn session_min_seq_extraction_is_strict_and_additive() {
        assert_eq!(session_min_seq(r#"{"id":1,"vector":[1.0],"k":2}"#), None);
        assert_eq!(
            session_min_seq(r#"{"id":1,"vector":[1.0],"k":2,"min_seq":31}"#),
            Some(31)
        );
        assert_eq!(session_min_seq(r#"{"min_seq":-4}"#), None, "negative rejected");
        assert_eq!(session_min_seq(r#"{"min_seq":"x"}"#), None, "non-numeric rejected");
        // The token must not break standard request parsing.
        let req =
            Request::parse(r#"{"id":1,"vector":[1.0,2.0],"k":2,"min_seq":31}"#).unwrap();
        assert!(matches!(req, Request::Query(_)));
    }

    /// A u64 fingerprint must survive the JSON trip exactly — that is
    /// why it travels as hex, not as an (f64-backed) number.
    #[test]
    fn fingerprint_info_roundtrips_u64_exactly() {
        let info = FingerprintInfo {
            id: 9,
            // > 2^53: would be rounded if carried as a JSON number.
            fingerprint: 0xdead_beef_cafe_f00d,
            seq: 41,
            live: 100,
        };
        let back = FingerprintInfo::parse(&info.to_json_line()).unwrap();
        assert_eq!(info, back);
        assert!(FingerprintInfo::parse(&error_line(1, "x")).is_err());
    }
}
